"""Mamba (S6) selective-state-space mixer with sequential scan + decode step.

TPU adaptation note (DESIGN.md §2): the original CUDA kernel fuses the
selective scan in SRAM; materializing the (B, T, d_inner, d_state) scan
inputs — as a naive associative-scan port would — is infeasible at Jamba
scale.  We keep the recurrence as a ``lax.scan`` over time with an
O(B·d_inner·d_state) carry (the TPU-idiomatic equivalent: sequential in T,
fully parallel over d_inner on the VPU), and an O(1) single-step update for
decode — which is what makes ``long_500k`` native for SSM/hybrid archs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import variance_scaling
from .scan_utils import chunked_scan

Array = jax.Array


def init_mamba(key, d_model: int, *, expand: int, d_state: int, d_conv: int,
               dtype=jnp.float32):
    di = expand * d_model
    dtr = max(d_model // 16, 1)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": variance_scaling(ks[0], (d_model, 2 * di), d_model, dtype),
        "conv_w": variance_scaling(ks[1], (d_conv, di), d_conv, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": variance_scaling(ks[2], (di, dtr + 2 * d_state), di, dtype),
        "dt_proj_w": variance_scaling(ks[3], (dtr, di), dtr, dtype),
        "dt_proj_b": jnp.log(jnp.expm1(  # softplus^-1 of dt in [1e-3, 1e-1]
            jnp.exp(jax.random.uniform(ks[4], (di,),
                    minval=jnp.log(1e-3), maxval=jnp.log(1e-1))))).astype(dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": variance_scaling(ks[5], (di, d_model), di, dtype),
    }


@dataclasses.dataclass
class MambaState:
    conv: Array   # (B, d_conv-1, di) rolling conv inputs
    ssm: Array    # (B, di, d_state)

    @staticmethod
    def init(batch: int, di: int, d_state: int, d_conv: int, dtype) -> "MambaState":
        return MambaState(
            conv=jnp.zeros((batch, d_conv - 1, di), dtype),
            ssm=jnp.zeros((batch, di, d_state), jnp.float32),
        )


jax.tree_util.register_dataclass(
    MambaState, data_fields=["conv", "ssm"], meta_fields=[])


def _ssm_params(p, xc: Array):
    """xc: (..., di) post-conv activations -> (dt, B, C) selective params."""
    d_state = p["A_log"].shape[1]
    dtr = p["dt_proj_w"].shape[0]
    dbc = jnp.einsum("...i,ij->...j", xc, p["x_proj"])
    dt, Bm, Cm = jnp.split(dbc, [dtr, dtr + d_state], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("...r,ri->...i", dt, p["dt_proj_w"])
                         + p["dt_proj_b"])                       # (..., di)
    return dt, Bm, Cm


def _ssm_step(p, h: Array, xc: Array, dt: Array, Bm: Array, Cm: Array):
    """One recurrence step. h: (B, di, S); xc/dt: (B, di); Bm/Cm: (B, S)."""
    A = -jnp.exp(p["A_log"])                                     # (di, S)
    dA = jnp.exp(dt[..., None] * A)                              # (B, di, S)
    dB = dt[..., None] * Bm[:, None, :]                          # (B, di, S)
    h = dA * h + dB * xc[..., None].astype(jnp.float32)
    y = jnp.einsum("bis,bs->bi", h, Cm) + p["D"] * xc
    return h, y.astype(xc.dtype)


def mamba_forward(p, x: Array, *, return_state: bool = False):
    """Full-sequence mixer. x: (B, T, d_model) -> (B, T, d_model).

    ``return_state=True`` additionally returns the final MambaState so a
    prefill pass can hand off to incremental decode."""
    B, T, _ = x.shape
    di = p["conv_b"].shape[0]
    d_conv = p["conv_w"].shape[0]
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    xi, z = jnp.split(xz, 2, axis=-1)                            # (B, T, di)
    # Depthwise causal conv along T.
    xpad = jnp.pad(xi, ((0, 0), (d_conv - 1, 0), (0, 0)))
    windows = jnp.stack([xpad[:, i : i + T] for i in range(d_conv)], axis=0)
    xc = jax.nn.silu(jnp.einsum("kbti,ki->bti", windows, p["conv_w"])
                     + p["conv_b"])
    dt, Bm, Cm = _ssm_params(p, xc)                              # (B, T, ·)

    def step(h, inp):
        xc_t, dt_t, B_t, C_t = inp
        h, y = _ssm_step(p, h, xc_t, dt_t, B_t, C_t)
        return h, y

    h0 = jnp.zeros((B, di, p["A_log"].shape[1]), jnp.float32)
    h_last, ys = chunked_scan(
        step, h0,
        (xc.swapaxes(0, 1), dt.swapaxes(0, 1),
         Bm.swapaxes(0, 1), Cm.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1)                                        # (B, T, di)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bti,id->btd", y, p["out_proj"])
    if not return_state:
        return out
    # Conv tail: last (d_conv-1) pre-conv inputs for incremental decode.
    tail = xi[:, -(d_conv - 1):, :] if T >= d_conv - 1 else jnp.pad(
        xi, ((0, 0), (d_conv - 1 - T, 0), (0, 0)))
    return out, MambaState(conv=tail, ssm=h_last)


def mamba_decode(p, x: Array, state: MambaState) -> tuple[Array, MambaState]:
    """One-token step. x: (B, 1, d_model)."""
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    xi, z = jnp.split(xz[:, 0], 2, axis=-1)                      # (B, di)
    conv_in = jnp.concatenate([state.conv, xi[:, None, :]], axis=1)  # (B, k, di)
    xc = jax.nn.silu(jnp.einsum("bki,ki->bi", conv_in, p["conv_w"])
                     + p["conv_b"])
    dt, Bm, Cm = _ssm_params(p, xc)
    h, y = _ssm_step(p, state.ssm, xc, dt, Bm, Cm)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"])[:, None, :]
    return out, MambaState(conv=conv_in[:, 1:], ssm=h)
