"""The paper's own model: 4 hidden layers × 2000 ReLU units, softmax output.

TIMIT frame classifier (§3): 351-d cepstral input, 39 phone classes,
dropout 0.2 between hidden layers.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers.common import variance_scaling

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DNNConfig:
    input_dim: int = 351
    hidden_dim: int = 2000
    n_hidden: int = 4
    n_classes: int = 39
    dropout: float = 0.2


def init_dnn(cfg: DNNConfig, key) -> dict:
    dims = [cfg.input_dim] + [cfg.hidden_dim] * cfg.n_hidden + [cfg.n_classes]
    ks = jax.random.split(key, len(dims) - 1)
    return {
        "layers": [
            {
                "w": variance_scaling(ks[i], (dims[i], dims[i + 1]), dims[i],
                                      scale=2.0),   # He init for ReLU
                "b": jnp.zeros((dims[i + 1],), jnp.float32),
            }
            for i in range(len(dims) - 1)
        ]
    }


def dnn_forward(params: dict, x: Array, *, dropout_rng=None,
                dropout: float = 0.0) -> Array:
    """x: (B, input_dim) -> logits (B, n_classes)."""
    h = x
    n = len(params["layers"])
    for i, layer in enumerate(params["layers"]):
        h = h @ layer["w"] + layer["b"]
        if i < n - 1:
            h = jax.nn.relu(h)
            if dropout_rng is not None and dropout > 0.0:
                dropout_rng, sub = jax.random.split(dropout_rng)
                keep = jax.random.bernoulli(sub, 1.0 - dropout, h.shape)
                h = jnp.where(keep, h / (1.0 - dropout), 0.0)
    return h


def dnn_hidden(params: dict, x: Array, *, layer: int = -1) -> Array:
    """Clean (dropout-free) forward returning hidden layer ``layer``'s
    post-ReLU activation — the embedding space the online affinity refresh
    taps (Bai et al. 1511.06104 build the graph from exactly this).

    ``layer`` indexes the hidden layers (negative counts from the last);
    the output head is never included — logits are not an embedding.
    """
    n_hidden = len(params["layers"]) - 1
    if not -n_hidden <= layer < n_hidden:
        raise ValueError(
            f"layer {layer} out of range for {n_hidden} hidden layers")
    stop = layer % n_hidden
    h = x
    for i, lyr in enumerate(params["layers"][:-1]):
        h = jax.nn.relu(h @ lyr["w"] + lyr["b"])
        if i == stop:
            return h
    return h
