"""Unified scan-compiled training engine (the one loop for every scenario).

One :class:`Engine` replaces the three divergent Python-stepped loops the
repo grew (sequential trainer, async parameter-server simulation, launcher
smoke path).  It compiles a whole epoch — or fixed-size chunks of steps —
into a single jitted ``lax.scan`` whose carry (:class:`TrainState`) is
**donated**, so per-step Python dispatch and per-step state copies both
disappear from the hot loop, and feeds the scan from a double-buffered
host→device prefetch iterator so the next chunk is stacked and transferred
while the current one computes.

How work is mapped onto devices is an *execution strategy*, looked up by
name in the ``repro.api.registry.STRATEGY`` registry:

  * ``"sequential"`` — single-device execution (state and batches on the
    default device);
  * ``"sync_mesh"``  — the paper's k-worker synchronous SGD: parameters
    replicated over a ``("data",)`` mesh, each chunk's worker axis sharded
    over it, pjit inserting the gradient all-reduce the parameter server
    performed;
  * ``"async_ps"``   — the §4 stale-gradient parameter-server simulation:
    each of k workers holds a snapshot up to ``max_staleness`` server steps
    old, gradients are taken at the snapshot and applied to the live
    parameters immediately (deterministic round-robin schedule, expressed
    entirely inside the scan body).

Periodic checkpointing (``checkpoint_every`` epochs into ``checkpoint_dir``)
saves the *strategy carry* — params, optimizer state, rng key, step counter,
and for async the snapshots/ages too — so ``run(..., resume=True)`` resumes
mid-run exactly: the restored run's history matches an uninterrupted run.
Host-side pipeline RNG is replayed by draining the skipped epochs' batch
iterators (data pass only, no compute).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import queue
import threading
import time
import warnings
from collections import deque
from typing import Any, Callable, Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.introspect import accepts_kwarg
from repro.resilience.guard import NonFiniteHaltError, all_finite, guard_init
from repro.resilience.supervisor import Supervisor
from repro.train.checkpoint import (atomic_write_text, load_checkpoint,
                                    save_checkpoint)

__all__ = [
    "TrainState",
    "EngineResult",
    "Engine",
    "MESH_AXIS",
    "data_mesh",
    "lift_step",
    "prefetch_to_device",
    "SequentialStrategy",
    "SyncMeshStrategy",
    "AsyncPSStrategy",
]

_LATEST = "LATEST"


# --------------------------------------------------------------------- state
@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["params", "opt_state", "rng", "step"],
                   meta_fields=[])
@dataclasses.dataclass
class TrainState:
    """The scan carry: everything a training step reads and writes.

    Pytree-registered so it flows through ``jit``/``scan``/``device_put``
    and checkpoints as a flat tree.  ``rng`` and ``step`` live *inside* the
    state so a restored checkpoint resumes the exact dropout stream and
    worker schedule.
    """

    params: Any
    opt_state: Any
    rng: jax.Array           # PRNG key consumed by the step (dropout etc.)
    step: jax.Array          # global step counter (int32 scalar)

    @classmethod
    def create(cls, params, opt_state, rng) -> "TrainState":
        return cls(params=params, opt_state=opt_state, rng=rng,
                   step=jnp.zeros((), jnp.int32))


@dataclasses.dataclass
class EngineResult:
    state: TrainState
    history: list[dict]      # per-epoch metric rows

    @property
    def params(self):
        return self.state.params


def lift_step(update_fn: Callable) -> Callable:
    """Adapt a raw ``(params, opt_state, batch, lr) -> (params, opt_state,
    metrics)`` update into an engine ``step_fn``: threads the step counter,
    leaves ``rng`` untouched (for rng-free steps like the LM path — steps
    that consume rng write their own adapter, as the SSL trainer does)."""

    def step_fn(state: TrainState, batch, lr):
        params, opt_state, metrics = update_fn(state.params, state.opt_state,
                                               batch, lr)
        return dataclasses.replace(state, params=params, opt_state=opt_state,
                                   step=state.step + 1), metrics

    return step_fn


#: The one mesh axis the training engine shards over.  Every collective
#: a strategy introduces must bind this name — it is the axis the S-pass
#: (``repro.analysis.sharding_audit``) checks the engine entry points'
#: declared ``EntryPoint.mesh_axes`` against.
MESH_AXIS = "data"


def data_mesh(n_workers: int):
    """``(MESH_AXIS,)`` mesh whose size is the largest divisor of
    ``n_workers`` realizable on the available devices (1 on a
    single-device host — the sharded arrays then simply live on that
    device)."""
    n_dev = len(jax.devices())
    size = max(d for d in range(1, min(n_workers, n_dev) + 1)
               if n_workers % d == 0)
    return jax.make_mesh((size,), (MESH_AXIS,))


# ------------------------------------------------------------------ prefetch
def prefetch_to_device(chunks: Iterable, put: Callable, depth: int = 2
                       ) -> Iterator:
    """Double-buffered host→device pipeline: a background thread stacks and
    transfers up to ``depth`` chunks ahead of the consumer, so host work and
    H2D copies overlap device compute.  ``depth <= 0`` degrades to a plain
    synchronous map (useful for debugging)."""
    if depth <= 0:
        for c in chunks:
            yield put(c)
        return
    q: queue.Queue = queue.Queue(maxsize=depth)
    sentinel = object()
    stop = threading.Event()
    errors: list[BaseException] = []

    def _put(item) -> bool:
        """Offer ``item`` until it fits or the consumer signalled stop."""
        while not stop.is_set():
            try:
                q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def producer():
        try:
            for c in chunks:
                if stop.is_set() or not _put(put(c)):
                    return
        except BaseException as e:  # noqa: BLE001 — re-raised on the consumer
            errors.append(e)
        finally:
            _put(sentinel)

    t = threading.Thread(target=producer, daemon=True,
                         name="engine-prefetch")
    t.start()
    try:
        while True:
            item = q.get()
            if item is sentinel:
                break
            yield item
        t.join()
        if errors:
            raise errors[0]
    finally:
        # Consumer gone early (exception in the training step, generator
        # closed): tell the producer to stop and unblock any pending put so
        # neither the thread nor its staged device buffers outlive this
        # iterator.
        stop.set()
        while True:
            try:
                q.get_nowait()
            except queue.Empty:
                break
        t.join(timeout=5.0)


def _as_host_dict(batch) -> dict:
    if dataclasses.is_dataclass(batch) and not isinstance(batch, dict):
        d = dataclasses.asdict(batch)
    else:
        d = dict(batch)
    # Optional batch fields (the SSLBatch tile layout when the pipeline has
    # no layout_bt) are None — drop them so chunk stacking and device
    # placement only ever see arrays.
    return {k: v for k, v in d.items() if v is not None}


def _stack_chunk(batches: list[dict]) -> dict:
    """Stack per-step host batches into one (S, ...) scan chunk."""
    return {k: np.stack([b[k] for b in batches]) for k in batches[0]}


# ---------------------------------------------------------------- strategies
class SequentialStrategy:
    """Single-device execution: the scan body is the step function itself."""

    def __init__(self, engine: "Engine"):
        self.engine = engine
        if engine.step_fn is None:
            raise ValueError(f"strategy {type(self).__name__} needs step_fn=")

    # Placement ----------------------------------------------------------
    def place_state(self, state: TrainState) -> TrainState:
        return state

    def place_batch(self, chunk: dict) -> dict:
        return jax.tree.map(jnp.asarray, chunk)

    def place_carry(self, carry):
        """Re-place a carry restored from a (host, numpy) checkpoint."""
        return jax.tree.map(jnp.asarray, carry)

    # Carry lifecycle ----------------------------------------------------
    def init_carry(self, state: TrainState):
        return state

    def begin_epoch(self, carry):
        return carry

    def state_of(self, carry) -> TrainState:
        return carry

    # Scan body ----------------------------------------------------------
    def body(self, carry, batch, lr):
        return self.engine.step_fn(carry, batch, lr)


class SyncMeshStrategy(SequentialStrategy):
    """The current pjit data-parallel path: params replicated over a
    ``("data",)`` mesh, each chunk's leading worker axis (axis 1 — axis 0 is
    the scan axis) sharded over it."""

    def __init__(self, engine: "Engine"):
        super().__init__(engine)
        if engine.mesh is None:
            raise ValueError("strategy 'sync_mesh' needs mesh= (a ('data',) "
                             "mesh); use repro.train.engine.data_mesh")
        P = jax.sharding.PartitionSpec
        self._replicated = jax.sharding.NamedSharding(engine.mesh, P())
        self._sharded = jax.sharding.NamedSharding(engine.mesh,
                                                   P(None, MESH_AXIS))

    def place_state(self, state: TrainState) -> TrainState:
        return jax.device_put(state, self._replicated)

    def place_batch(self, chunk: dict) -> dict:
        return jax.tree.map(
            lambda a: jax.device_put(jnp.asarray(a), self._sharded), chunk)

    def place_carry(self, carry):
        return jax.device_put(carry, self._replicated)


class AsyncPSStrategy:
    """Stale-gradient parameter-server simulation as a scan body.

    Carry = (state, snapshots, ages, t): ``snapshots`` stacks k per-worker
    parameter copies, ``ages[w]`` counts pushes since worker w last pulled,
    ``t`` is the epoch-local step (the round-robin schedule restarts each
    epoch, matching the reference simulation).  Worker ``t % k`` computes a
    gradient at its snapshot via ``engine.grad_fn`` (which shares
    ``dnn_ssl_step``'s loss plumbing and the PAIRWISE registry); the server
    applies it to the live params immediately; the worker pulls fresh params
    once its age reaches ``max_staleness``.
    """

    def __init__(self, engine: "Engine"):
        self.engine = engine
        if engine.grad_fn is None or engine.opt is None:
            raise ValueError("strategy 'async_ps' needs grad_fn= and opt=")
        self.k = engine.n_workers
        self.max_staleness = engine.max_staleness
        self.drop_overstale = bool(
            getattr(engine.resilience, "drop_overstale", False))

    # Placement ----------------------------------------------------------
    def place_state(self, state: TrainState) -> TrainState:
        return state

    def place_batch(self, chunk: dict) -> dict:
        return jax.tree.map(jnp.asarray, chunk)

    def place_carry(self, carry):
        return jax.tree.map(jnp.asarray, carry)

    # Carry lifecycle ----------------------------------------------------
    def init_carry(self, state: TrainState):
        snapshots = jax.tree.map(lambda p: jnp.stack([p] * self.k),
                                 state.params)
        ages = jnp.zeros((self.k,), jnp.int32)
        return (state, snapshots, ages, jnp.zeros((), jnp.int32))

    def begin_epoch(self, carry):
        state, snapshots, ages, _ = carry
        return (state, snapshots, ages, jnp.zeros((), jnp.int32))

    def state_of(self, carry) -> TrainState:
        return carry[0]

    # Fault hooks --------------------------------------------------------
    def bump_age(self, carry, worker: int, amount: float):
        """Host-side injection hook: age worker ``worker % k`` by
        ``amount`` pushes (default: past ``max_staleness``, i.e. dead)."""
        state, snapshots, ages, t = carry
        amt = int(amount) or (self.max_staleness + 1)
        ages = ages.at[int(worker) % self.k].add(jnp.int32(amt))
        return (state, snapshots, ages, t)

    # Scan body ----------------------------------------------------------
    def body(self, carry, batch, lr):
        state, snapshots, ages, t = carry
        w = t % self.k
        snap_w = jax.tree.map(lambda s: s[w], snapshots)
        grads, metrics = self.engine.grad_fn(snap_w, batch)
        if self.drop_overstale:
            # A snapshot older than max_staleness is a dead/straggler
            # worker: drop its gradient (zero-gradient server update keeps
            # params and adagrad accumulators unchanged) and renormalize
            # the survivors' contribution so the effective per-pass
            # gradient mass matches the all-alive schedule.
            live = ages <= self.max_staleness
            n_live = jnp.maximum(jnp.sum(live.astype(jnp.int32)), 1)
            scale = jnp.where(live[w], self.k / n_live, 0.0).astype(
                jnp.float32)
            grads = jax.tree.map(
                lambda g: (g * scale).astype(g.dtype), grads)
            metrics = dict(metrics)
            metrics["async/dropped"] = 1.0 - jnp.where(live[w], 1.0, 0.0)
        params, opt_state = self.engine.opt.update(
            grads, state.opt_state, state.params, lr)
        ages = ages.at[w].add(1)
        refresh = ages[w] >= self.max_staleness
        snapshots = jax.tree.map(
            lambda s, p: s.at[w].set(jnp.where(refresh, p, s[w])),
            snapshots, params)
        ages = ages.at[w].set(jnp.where(refresh, 0, ages[w]))
        state = TrainState(params=params, opt_state=opt_state,
                           rng=state.rng, step=state.step + 1)
        return (state, snapshots, ages, t + 1), metrics


# -------------------------------------------------------------------- engine
class Engine:
    """Scan-compiled trainer: one jitted ``lax.scan`` per chunk of steps.

    Args:
      step_fn: ``(state, batch, lr) -> (state, metrics)`` — the per-step
        update used by ``sequential``/``sync_mesh`` (and any custom strategy
        that calls it).
      grad_fn: ``(params, batch) -> (grads, metrics)`` — gradient at given
        (possibly stale) params; required by ``async_ps``.
      opt: the ``repro.optim.Optimizer`` applying server updates
        (``async_ps`` only — synchronous strategies fold the update into
        ``step_fn``).
      strategy: STRATEGY registry name or an already-constructed instance.
      scan_chunk: steps per compiled scan; 0 compiles the whole epoch.
      prefetch: host→device prefetch depth (2 = double buffering; 0 = off).
      checkpoint_every/checkpoint_dir: save the full strategy carry every N
        epochs; ``run(..., resume=True)`` restores the newest one.
      resilience: an (optional) ``ResilienceConfig``-shaped object enabling
        the defenses — ``nonfinite_guard`` (plain scan body plus one
        per-chunk finiteness reduction folded into a ``tainted`` flag,
        resolved once per ``guard_window`` chunks; tainted windows are
        replayed from a window-start backup with the strict
        update-skipping body, which recomputes exact skipped-step
        accounting), ``halt_after_consecutive`` (host-side
        :class:`NonFiniteHaltError` policy), ``checkpoint_checksums`` /
        ``keep_last`` (integrity + retention), ``drop_overstale``
        (async_ps survivor renormalization), and the supervisor's retry /
        backoff / hang-timeout knobs for the prefetch producer.
      injector: an (optional) ``repro.resilience.FaultInjector`` whose
        batch / prefetch / checkpoint / worker hooks fire at their planned
        coordinates (chaos testing only — ``None`` in production).
      supervisor: override the prefetch supervisor (tests inject a
        no-sleep one); by default one is built from ``resilience``.
      capture_fn: ``(params, batch) -> array`` — optional per-step embedding
        tap (the online affinity refresh uses the hidden activations).  On
        epochs selected by ``run(..., capture_epochs=...)`` it is evaluated
        inside the scan body at the *post-step* params and its outputs ride
        the stacked scan metrics (ys, not the donated carry — donation-safe)
        back to the host, where ``on_epoch_end`` receives them concatenated
        over the epoch's steps.  Off-epochs compile the exact same body as
        ``capture_fn=None`` (the flag is a jit-static arg), so the hook is
        zero-cost when idle.
    """

    def __init__(
        self,
        step_fn: Callable | None = None,
        *,
        grad_fn: Callable | None = None,
        opt=None,
        strategy: str | Any = "sequential",
        mesh=None,
        n_workers: int = 1,
        max_staleness: int = 2,
        scan_chunk: int = 0,
        prefetch: int = 2,
        checkpoint_every: int = 0,
        checkpoint_dir: str | None = None,
        resilience=None,
        injector=None,
        supervisor: Supervisor | None = None,
        capture_fn: Callable | None = None,
    ):
        if scan_chunk < 0:
            raise ValueError(f"scan_chunk must be >= 0, got {scan_chunk}")
        if checkpoint_every < 0:
            raise ValueError(
                f"checkpoint_every must be >= 0, got {checkpoint_every}")
        if checkpoint_every > 0 and not checkpoint_dir:
            raise ValueError("checkpoint_every > 0 requires checkpoint_dir")
        self.step_fn = step_fn
        self.grad_fn = grad_fn
        self.opt = opt
        self.mesh = mesh
        self.n_workers = n_workers
        self.max_staleness = max_staleness
        self.scan_chunk = scan_chunk
        self.prefetch = prefetch
        self.checkpoint_every = checkpoint_every
        self.checkpoint_dir = checkpoint_dir
        # Resilience knobs are duck-typed off the config object so the
        # engine stays constructible without repro.api; all defaults
        # reproduce the pre-resilience behaviour exactly.
        self.resilience = resilience
        self.injector = injector
        self.capture_fn = capture_fn
        self._guard = bool(getattr(resilience, "nonfinite_guard", False))
        self._halt_after = int(
            getattr(resilience, "halt_after_consecutive", 0) or 0)
        self._checksums = bool(
            getattr(resilience, "checkpoint_checksums", True))
        self._keep_last = int(getattr(resilience, "keep_last", 0) or 0)
        if supervisor is None and resilience is not None:
            supervisor = Supervisor.from_config(resilience, name="prefetch")
        self.supervisor = supervisor
        if isinstance(strategy, str):
            # Lazy import: keeps repro.train importable without repro.api
            # having been set up first (no cycle either way — api.registry
            # only *names* this module).
            from repro.api.registry import STRATEGY
            strategy = STRATEGY.get(strategy)(self)
        self.strategy = strategy
        # Guarded chunks resolve in windows of this many chunks: one guard-
        # scalar fetch (and one replay backup + retained placed chunks) per
        # window instead of per chunk.
        self._guard_window = max(
            1, int(getattr(resilience, "guard_window", 4) or 4))
        # One jitted scan per chunk length (jit caches by shape).  The
        # carry is donated so state buffers are reused in place chunk to
        # chunk — except at a guard window's first chunk, whose *undonated*
        # input carry survives the call and serves as the free backup a
        # tainted window's strict replay restarts from.
        # ``capture`` is static: an off-epoch traces the identical body a
        # capture-free engine would, a capture epoch gets its own cached
        # executable with the embedding ys added.
        self._chunk_fn = jax.jit(self._run_chunk, donate_argnums=(0,),
                                 static_argnums=(3,))
        self._chunk_keep = jax.jit(self._run_chunk, static_argnums=(3,))
        # The strict guard body only compiles if a window ever needs the
        # replay (lazily, on first call) — clean runs never pay for it.
        self._strict_fn = jax.jit(self._run_chunk_strict, static_argnums=(3,))

    # ---------------------------------------------------------------- scan
    #: Metrics key the capture tap rides under; popped out of the metric
    #: chunks (and concatenated for ``on_epoch_end``) before row averaging.
    _CAPTURE_KEY = "capture/emb"

    def _step_body(self, lr, capture: bool):
        """The scan body, optionally extended with the embedding tap."""
        def body(c, b):
            c2, m = self.strategy.body(c, b, lr)
            if capture:
                m = dict(m)
                m[self._CAPTURE_KEY] = self.capture_fn(
                    self.strategy.state_of(c2).params, b)
            return c2, m

        return body

    def _run_chunk(self, carry, batches, lr, capture: bool = False):
        """The hot path.  With the guard on the scan body is *identical* to
        the unguarded one — no per-step check, count, or select.  The only
        additions are a single post-scan finiteness reduction over the
        chunk's final carry and stacked per-step metrics, folded into a
        ``tainted`` flag threaded through the carry, and a ``guard/skipped``
        zeros column so metric rows keep one schema.  The run loop fetches
        the guard scalars once per *window* of chunks; a tainted window is
        discarded and replayed from its start with
        :meth:`_run_chunk_strict`, which recomputes the exact skip
        accounting.  Clean windows — the overwhelming case — pay one
        finiteness reduction per chunk and one scalar fetch per window."""
        body = self._step_body(lr, capture)

        if not self._guard:
            return jax.lax.scan(body, carry, batches)

        sc, (skipped, consec, worst, tainted) = carry
        out_sc, metrics = jax.lax.scan(body, sc, batches)
        # Stacked metrics give per-step visibility, so even a transient
        # non-finite that the carry later masks still taints the window.
        ok = all_finite((out_sc, metrics))
        n_steps = jax.tree_util.tree_leaves(batches)[0].shape[0]
        metrics = dict(metrics)
        metrics["guard/skipped"] = jnp.zeros((n_steps,), jnp.float32)
        # A clean chunk proves every step was fine, so the consecutive
        # counter resets; on taint its value is garbage anyway — the strict
        # replay restarts from the window backup's (correct) guard state.
        guard = (skipped, jnp.where(ok, jnp.int32(0), consec), worst,
                 jnp.logical_or(tainted, ~ok))
        return (out_sc, guard), metrics

    def _run_chunk_strict(self, carry, batches, lr, capture: bool = False):
        """The replay path for a window the hot pass tainted: the per-step
        guarded body with exact skip accounting."""
        body = self._step_body(lr, capture)

        def guarded(c, b):
            sc, (skipped, consec, worst) = c
            new_sc, metrics = body(sc, b)
            ok = all_finite((new_sc, metrics))
            # Skip the whole update on a non-finite step: params, opt
            # state, rng, step counter — the carry is exactly what it was,
            # as if the poisoned batch had never been drawn.
            keep = jax.lax.cond(ok, lambda: new_sc, lambda: sc)
            bad = (~ok).astype(jnp.int32)
            consec = jnp.where(ok, jnp.int32(0), consec + 1)
            # Zero the skipped step's metrics so epoch means stay finite.
            metrics = jax.tree.map(
                lambda m: jnp.where(ok, m, jnp.zeros_like(m)), metrics)
            metrics = dict(metrics)
            metrics["guard/skipped"] = bad.astype(jnp.float32)
            guard = (skipped + bad, consec, jnp.maximum(worst, consec))
            return (keep, guard), metrics

        sc, (skipped, consec, worst, _) = carry
        (out_sc, counters), metrics = jax.lax.scan(
            guarded, (sc, (skipped, consec, worst)), batches)
        return (out_sc, (*counters, jnp.zeros((), jnp.bool_))), metrics

    # Guard carry plumbing: with the guard on, the jitted carry is
    # ``(strategy_carry, (skipped_total, consecutive, worst, tainted))`` —
    # these helpers keep strategy lifecycle hooks working on their own
    # carry.
    def _wrap_carry(self, strategy_carry, guard_state=None):
        if not self._guard:
            return strategy_carry
        return (strategy_carry, guard_state or guard_init())

    def _split_carry(self, carry):
        if not self._guard:
            return carry, None
        return carry

    def _bump(self, strategy, carry, bump):
        """Apply a recorded worker-age bump to the (wrapped) carry — used
        both on first dispatch and when a strict replay re-dispatches the
        chunks younger than a poisoned one."""
        if bump is None:
            return carry
        sc, gs = self._split_carry(carry)
        return self._wrap_carry(strategy.bump_age(sc, bump[0], bump[1]), gs)

    def _host_chunks(self, batch_iter: Iterable, epoch: int = 0
                     ) -> Iterator[dict]:
        """Group host batches into stacked (S, ...) scan chunks (poisoning
        any step with an armed batch-site fault event)."""
        pending: list[dict] = []
        step = 0
        for b in batch_iter:
            h = _as_host_dict(b)
            if self.injector is not None:
                h = self.injector.on_batch(h, epoch=epoch, step=step)
            step += 1
            pending.append(h)
            if self.scan_chunk and len(pending) == self.scan_chunk:
                yield _stack_chunk(pending)
                pending = []
        if pending:
            yield _stack_chunk(pending)

    # ---------------------------------------------------------- checkpoints
    def _ckpt_path(self, epoch: int) -> str:
        return os.path.join(self.checkpoint_dir, f"ckpt_{epoch:05d}")

    def _save(self, carry, epoch: int, history: list[dict]) -> None:
        path = self._ckpt_path(epoch)
        save_checkpoint(path, carry, checksum=self._checksums)
        atomic_write_text(path + ".meta.json",
                          json.dumps({"epoch": epoch, "history": history}))
        atomic_write_text(os.path.join(self.checkpoint_dir, _LATEST),
                          os.path.basename(path))
        if self.injector is not None:
            # Simulated bit rot / torn write of the file LATEST points at —
            # AFTER the pointer update, so recovery must fall back.
            self.injector.after_checkpoint(path + ".npz", epoch=epoch)
        if self._keep_last:
            self._prune(keep=os.path.basename(path))

    def _prune(self, keep: str) -> None:
        """Drop all but the newest ``keep_last`` checkpoints (never the one
        just written).  Epoch numbers order lexically at fixed width."""
        names = sorted(
            (f[:-len(".npz")] for f in os.listdir(self.checkpoint_dir)
             if f.startswith("ckpt_") and f.endswith(".npz")), reverse=True)
        for base in names[self._keep_last:]:
            if base == keep:
                continue
            stem = os.path.join(self.checkpoint_dir, base)
            for suffix in (".npz", ".npz.sha256", ".meta.json"):
                if os.path.exists(stem + suffix):
                    os.remove(stem + suffix)

    def _load_latest(self, template_carry):
        """(carry, completed_epochs, history) from the newest *valid*
        checkpoint, or None when the directory holds none.

        The LATEST pointer's target is tried first; if it is corrupt
        (checksum mismatch, torn archive, unreadable meta) the remaining
        ``ckpt_*`` files are tried newest-first, each failure downgraded
        to a warning — a crash or bit flip costs at most the epochs since
        the last good save, never the run.
        """
        if not self.checkpoint_dir or not os.path.isdir(self.checkpoint_dir):
            return None
        pointer = os.path.join(self.checkpoint_dir, _LATEST)
        candidates: list[str] = []
        if os.path.exists(pointer):
            with open(pointer) as f:
                candidates.append(f.read().strip())
        candidates += sorted(
            (f[:-len(".npz")] for f in os.listdir(self.checkpoint_dir)
             if f.startswith("ckpt_") and f.endswith(".npz")), reverse=True)
        seen: set[str] = set()
        for base in candidates:
            if not base or base in seen:
                continue
            seen.add(base)
            path = os.path.join(self.checkpoint_dir, base)
            try:
                carry = load_checkpoint(path, template_carry,
                                        verify=self._checksums)
                with open(path + ".meta.json") as f:
                    meta = json.load(f)
                epoch, hist = int(meta["epoch"]), list(meta["history"])
            except Exception as e:  # noqa: BLE001 — degrade to older ckpt
                warnings.warn(
                    f"checkpoint {base} is unusable "
                    f"({type(e).__name__}: {e}); falling back to the next "
                    "newest", stacklevel=2)
                continue
            return (self.strategy.place_carry(carry), epoch, hist)
        return None

    # ----------------------------------------------------------------- run
    def run(
        self,
        pipeline_epoch: Callable[[], Iterable],
        *,
        state: TrainState,
        n_epochs: int,
        lr_schedule: Callable[[int], float],
        eval_fn: Callable[[Any], dict] | None = None,
        resume: bool = False,
        capture_epochs: Callable[[int], bool] | Any = None,
        on_epoch_end: Callable[[int, Any, Any], None] | None = None,
    ) -> EngineResult:
        """Train for ``n_epochs`` passes of ``pipeline_epoch()`` batches.

        ``pipeline_epoch`` is called once per epoch and must yield host
        batches (dicts or dataclasses of equal-shaped numpy arrays).
        Accepting an ``epoch=`` keyword declares the pipeline *epoch-pure*:
        the true epoch index is passed, resume skips the host-side replay
        of earlier epochs entirely (an epoch-pure pipeline reproduces any
        epoch from its index alone — the re-partitioning stream does), and
        an ``n_epochs=`` keyword additionally receives the horizon (so the
        stream can skip pre-computing plans no epoch will consume).
        ``eval_fn(params) -> dict`` is merged into each epoch row.  With
        ``resume=True`` and a checkpoint present in ``checkpoint_dir``,
        training restarts from the saved carry/epoch; for epoch-blind
        pipelines the skipped epochs' batch iterators are drained so
        host-side pipeline RNG replays the exact stream an uninterrupted
        run would have seen.

        ``capture_epochs`` (a predicate ``epoch -> bool``, or a container
        of epoch indices) selects the epochs whose steps evaluate the
        engine's ``capture_fn``; ``on_epoch_end(epoch, params, captures)``
        then fires after every epoch row with the epoch's captures stacked
        ``(steps, ...)`` on the host (``None`` on non-capture epochs) —
        the online refresh hook.  On a guard-replayed window, skipped
        steps' captures are zeroed like their metrics.
        """
        strategy = self.strategy
        # Epoch purity is a semantic contract — only an explicitly named
        # ``epoch`` parameter opts in (a **kwargs catch-all does not).
        takes_epoch = accepts_kwarg(pipeline_epoch, "epoch", explicit=True)
        extra = ({"n_epochs": n_epochs}
                 if takes_epoch and accepts_kwarg(pipeline_epoch, "n_epochs",
                                                  explicit=True)
                 else {})

        def epoch_batches(e: int):
            return pipeline_epoch(epoch=e, **extra) if takes_epoch \
                else pipeline_epoch()

        start, history = 0, []
        # Copy the initial leaves: the first chunk call DONATES the carry,
        # and caller-owned buffers (e.g. a params pytree reused across runs)
        # must survive this run.
        state = jax.tree.map(lambda x: jnp.array(x), state)
        carry = self._wrap_carry(strategy.init_carry(
            strategy.place_state(state)))
        if resume:
            loaded = self._load_latest(carry)
            if loaded is not None:
                carry, start, history = loaded
        if start < n_epochs and not takes_epoch:
            # Epoch-blind pipelines advance host RNG per call: replay the
            # skipped epochs (data pass only, no compute).  Epoch-pure
            # pipelines reproduce epoch ``start`` from its index directly.
            for past in range(start):
                for _ in epoch_batches(past):
                    pass
        def capture_on(e: int) -> bool:
            if self.capture_fn is None or capture_epochs is None:
                return False
            if callable(capture_epochs):
                return bool(capture_epochs(e))
            return e in capture_epochs

        for epoch in range(start, n_epochs):
            lr = jnp.float32(lr_schedule(epoch))
            cap = capture_on(epoch)
            t0 = time.time()
            sc, gs = self._split_carry(carry)
            carry = self._wrap_carry(strategy.begin_epoch(sc), gs)
            metric_chunks = []
            put = strategy.place_batch
            if self.injector is not None:
                put = self.injector.wrap_put(put, epoch=epoch)
            if self.supervisor is not None:
                put = functools.partial(self.supervisor.call, put,
                                        key=f"prefetch@{epoch}")
            chunks = prefetch_to_device(
                self._host_chunks(epoch_batches(epoch), epoch),
                put, self.prefetch)
            # Guarded chunks are grouped into windows of ``guard_window``
            # chunks.  Each window keeps its start carry (undonated — the
            # replay backup) and its placed chunks; one guard-scalar fetch
            # per window, resolved one chunk behind the dispatch so the
            # fetch overlaps the successor's compute.  Each window item is
            # ``[chunk_idx, placed, metrics]``.
            win: list = []                  # the window currently filling
            win_backup = None               # carry before win[0]
            done: deque = deque()           # (backup, items, carry_out)
            bumps: dict[int, tuple] = {}    # chunk_idx -> (worker, amount)

            def dispatch(item):
                nonlocal carry, win_backup
                first = not win
                if first:
                    win_backup = carry
                carry = self._bump(strategy, carry, bumps.get(item[0]))
                # The window's first chunk must not donate its input: the
                # backup has to survive for a possible strict replay.
                carry, item[2] = (self._chunk_keep if first else
                                  self._chunk_fn)(carry, item[1], lr, cap)
                win.append(item)
                if len(win) == self._guard_window:
                    done.append((win_backup, win[:], carry))
                    win.clear()

            def resolve_window():
                nonlocal carry
                backup, items, out = done.popleft()
                gs = self._split_carry(out)[1]
                skipped, worst, tainted = (
                    v.item() for v in
                    jax.device_get((gs[0], gs[2], gs[3])))
                if tainted:
                    # Non-finite step(s) somewhere in this window: discard
                    # the hot pass and replay the window strictly from its
                    # backup, skipping exactly the poisoned steps; then
                    # re-dispatch everything younger, which consumed the
                    # poisoned carry.
                    cur = backup
                    for item in items:
                        cur = self._bump(strategy, cur, bumps.get(item[0]))
                        cur, item[2] = self._strict_fn(cur, item[1], lr, cap)
                    gs = self._split_carry(cur)[1]
                    skipped, worst = (int(v) for v in
                                      jax.device_get((gs[0], gs[2])))
                    younger = [it for _, its, _ in done for it in its]
                    younger += win
                    done.clear()
                    win.clear()
                    carry = cur
                    for item in younger:
                        dispatch(item)
                metric_chunks.extend(item[2] for item in items)
                if self._halt_after and worst >= self._halt_after:
                    # Exact at window edges (the strict replay above just
                    # recomputed it when this window held the poison).
                    raise NonFiniteHaltError(
                        f"{worst} consecutive non-finite steps "
                        f"(halt_after_consecutive={self._halt_after}) "
                        f"at epoch {epoch}")

            for chunk_idx, placed in enumerate(chunks):
                if self.injector is not None and \
                        hasattr(strategy, "bump_age"):
                    ev = self.injector.take("worker", epoch=epoch,
                                            step=chunk_idx)
                    if ev is not None:
                        # Recorded so a tainted window's re-dispatch of
                        # this chunk re-applies the same age bump.
                        bumps[chunk_idx] = (ev.worker, ev.arg)
                if not self._guard:
                    carry = self._bump(strategy, carry, bumps.get(chunk_idx))
                    carry, metrics = self._chunk_fn(carry, placed, lr, cap)
                    metric_chunks.append(metrics)   # fetched after the epoch
                    continue
                dispatch([chunk_idx, placed, None])
                if done and (win or len(done) > 1):
                    resolve_window()
            while done or win:
                if win and not done:        # roll the final partial window
                    done.append((win_backup, win[:], carry))
                    win.clear()
                resolve_window()
            if not metric_chunks:
                # e.g. n_meta < n_workers: the pipeline had nothing to yield.
                warnings.warn(
                    f"epoch {epoch}: pipeline yielded no batches "
                    "(n_meta < n_workers?); skipping epoch row", stacklevel=2)
                continue
            captures = None
            if cap:
                # Pull the tap out of the metric chunks (it must not enter
                # the row means) and stack it (total_steps, ...) on host.
                captures = np.concatenate(
                    [np.asarray(jax.device_get(mc.pop(self._CAPTURE_KEY)))
                     for mc in metric_chunks])
            row = {
                k: float(np.mean(np.concatenate(
                    [np.asarray(mc[k]) for mc in metric_chunks])))
                for k in metric_chunks[0]
            }
            row.update(epoch=epoch, lr=float(lr), seconds=time.time() - t0)
            if self._guard:
                row["guard/skipped_total"] = int(
                    jax.device_get(self._split_carry(carry)[1][0]))
            if eval_fn is not None:
                row.update(eval_fn(
                    strategy.state_of(self._split_carry(carry)[0]).params))
            history.append(row)
            if on_epoch_end is not None:
                on_epoch_end(
                    epoch,
                    strategy.state_of(self._split_carry(carry)[0]).params,
                    captures)
            if self.checkpoint_every and \
                    (epoch + 1) % self.checkpoint_every == 0:
                self._save(carry, epoch + 1, history)
        return EngineResult(
            state=strategy.state_of(self._split_carry(carry)[0]),
            history=history)
