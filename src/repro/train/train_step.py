"""Train steps: the paper's SSL DNN step and the LM steps for assigned archs.

``dnn_ssl_step``   — the paper's objective (Eq. 3) on the 4×2000 DNN, over a
                     (k, P, ·) stack of concatenated meta-batches.  Under the
                     launcher the leading axis is sharded over ("pod","data"),
                     which *is* the paper's k-worker synchronous SGD: pjit
                     inserts the gradient all-reduce the parameter server did.
``lm_train_step``  — next-token loss for any assigned architecture, with the
                     paper's graph regularizer attached at the sequence level
                     (pooled output distribution + dense affinity block W).
``lm_supervised_step`` — same without the SSL terms (the paper's
                     fully-supervised baseline, and the dry-run default).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ssl_loss import SSLHyper, ssl_objective
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.models.dnn import DNNConfig, dnn_forward
from repro.optim import Optimizer

Array = jax.Array


# ------------------------------------------------------------------ DNN/SSL
#: SSLBatch block-layout fields, in ``BlockLayout.arrays()`` order — the
#: tuple the layout-aware pairwise kernels consume.
_TILE_KEYS = ("tile_rows", "tile_cols", "tile_valid",
              "tile_crows", "tile_ccols", "tile_cvalid", "tile_occ")


def dnn_ssl_loss(params, batch: dict, cfg: DNNConfig, hyper: SSLHyper,
                 *, dropout_rng=None, dropout: float = 0.0, pairwise=None):
    """Mean Eq.-3 loss over the k stacked concatenated batches.

    ``pairwise`` names a PAIRWISE registry entry ("ref" | "pallas" |
    "fused" | "blocksparse" | "auto") or is an already-resolved
    ``(logp, W) -> scalar`` callable; ``None`` keeps the inline jnp oracle.
    When the pipeline attached a block layout (the ``tile_*`` batch keys,
    from ``BatchConfig.layout_bt``) it rides through the vmap and into
    layout-aware kernels, which skip W's structurally-zero tiles.
    """
    tile_args = ([batch[k] for k in _TILE_KEYS]
                 if all(batch.get(k) is not None for k in _TILE_KEYS)
                 else [])

    def per_worker(x, y, mask, W, valid, *tiles):
        logits = dnn_forward(params, x, dropout_rng=dropout_rng,
                             dropout=dropout)
        # Padding rows: zero affinity + zero label mask + masked entropy term.
        mask = mask * valid
        Wm = W * valid[:, None] * valid[None, :]
        loss, metrics = ssl_objective(
            logits, y, mask, Wm, hyper, params=params, pairwise=pairwise,
            layout=tuple(tiles) or None, reduction="mean")
        return loss, metrics

    losses, metrics = jax.vmap(per_worker)(
        batch["x"], batch["y"], batch["label_mask"], batch["W"],
        batch["valid"].astype(jnp.float32), *tile_args)
    return jnp.mean(losses), jax.tree.map(jnp.mean, metrics)


def dnn_ssl_grads(params, batch: dict, *, cfg: DNNConfig, hyper: SSLHyper,
                  dropout_rng=None, dropout: float = 0.0, pairwise=None):
    """``(grads, metrics)`` of the Eq.-3 loss at ``params``.

    The shared gradient core: ``dnn_ssl_step`` applies it synchronously;
    the engine's ``async_ps`` strategy evaluates it at a *stale* parameter
    snapshot and hands the gradient to the server update — both through the
    same loss plumbing and PAIRWISE registry selection.
    """
    (loss, metrics), grads = jax.value_and_grad(
        dnn_ssl_loss, has_aux=True)(params, batch, cfg, hyper,
                                    dropout_rng=dropout_rng, dropout=dropout,
                                    pairwise=pairwise)
    metrics["loss/total"] = loss
    return grads, metrics


def dnn_ssl_step(params, opt_state, batch: dict, *, cfg: DNNConfig,
                 hyper: SSLHyper, opt: Optimizer, lr: Array,
                 dropout_rng=None, dropout: float = 0.0, pairwise=None):
    grads, metrics = dnn_ssl_grads(params, batch, cfg=cfg, hyper=hyper,
                                   dropout_rng=dropout_rng, dropout=dropout,
                                   pairwise=pairwise)
    new_params, new_state = opt.update(grads, opt_state, params, lr)
    return new_params, new_state, metrics


# ------------------------------------------------------------------- LM
def chunked_ce(x: Array, head: Array, targets: Array, mask: Array,
               *, chunk: int = 512) -> Array:
    """Cross-entropy over (B, T) without a live (B, T, V) logits tensor.

    Scans T in chunks of ``chunk``; each chunk's logits are recomputed in the
    backward pass (jax.checkpoint), so peak memory is O(B·chunk·V) — the
    difference between 80 GB and <1 GB per device at vocab≈150k.
    """
    B, T, d = x.shape
    c = min(chunk, T)
    pad = (-T) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nt = (T + pad) // c

    def body(carry, inp):
        xc, tc, mc = inp                       # (B, c, d), (B, c), (B, c)
        logits = jnp.einsum("bcd,dv->bcv", xc, head).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        picked = jnp.take_along_axis(logp, tc[..., None].astype(jnp.int32),
                                     axis=-1)[..., 0]
        return (carry[0] - jnp.sum(picked * mc), carry[1] + jnp.sum(mc)), None

    xs = (x.reshape(B, nt, c, d).swapaxes(0, 1),
          targets.reshape(B, nt, c).swapaxes(0, 1),
          mask.reshape(B, nt, c).swapaxes(0, 1))
    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body),
                                 (jnp.float32(0), jnp.float32(0)), xs)
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, cfg: ModelConfig, batch: dict, hyper: SSLHyper | None,
            *, pairwise=None, act_sharding=None):
    """Next-token CE (+ optional sequence-level SSL graph regularizer)."""
    out = tf.forward(params, cfg, batch["tokens"],
                     modality_embeds=batch.get("modality_embeds"),
                     act_sharding=act_sharding, with_logits=False)
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(batch["targets"].shape, jnp.float32)
    ce = chunked_ce(out["hidden"], tf.output_head(params, cfg),
                    batch["targets"], mask)
    loss = ce + 0.01 * out["moe_aux"]
    metrics = {"loss/ce": ce, "loss/moe_aux": out["moe_aux"]}
    if hyper is not None and "W" in batch:
        # Sequence-level graph regularizer over G independent concatenated
        # meta-batches (paper §2.3: the loss decomposes over groups; the
        # leading G axis is what the launcher shards over data — no
        # cross-worker SSL collective, exactly the paper's decomposition).
        G, b, _ = batch["W"].shape
        pooled = out["pooled_logits"].astype(jnp.float32).reshape(
            G, b, -1)

        def per_group(pl, y, m, W):
            return ssl_objective(pl, y, m, W, hyper, params=None,
                                 pairwise=pairwise, reduction="mean")

        ssl_losses, ssl_metrics = jax.vmap(per_group)(
            pooled, batch["seq_labels"], batch["seq_label_mask"], batch["W"])
        loss = loss + jnp.mean(ssl_losses)
        metrics.update({f"ssl/{k.split('/')[-1]}": jnp.mean(v)
                        for k, v in ssl_metrics.items()})
    metrics["loss/total"] = loss
    return loss, metrics


def lm_train_step(params, opt_state, batch: dict, *, cfg: ModelConfig,
                  hyper: SSLHyper | None, opt: Optimizer, lr,
                  pairwise=None, act_sharding=None):
    (loss, metrics), grads = jax.value_and_grad(lm_loss, has_aux=True)(
        params, cfg, batch, hyper, pairwise=pairwise,
        act_sharding=act_sharding)
    new_params, new_state = opt.update(grads, opt_state, params, lr)
    return new_params, new_state, metrics


def lm_supervised_step(params, opt_state, batch: dict, *, cfg: ModelConfig,
                       opt: Optimizer, lr):
    return lm_train_step(params, opt_state, batch, cfg=cfg, hyper=None,
                         opt=opt, lr=lr)
