"""Sequential / data-parallel SSL training for the paper's experiments.

Reproduces the paper's §3 protocol: AdaGrad, base lr 1e-3, effective lr
``1e-3·k`` reset after 10 epochs, dropout 0.2, batch size 1024/2048, label
ratios 2–100%.  The same entry point drives the fully-supervised baseline
(γ=κ=0), the random-batch baseline, and the meta-batch method — only the
pipeline and hyper-parameters change.

``train_dnn_ssl`` is a thin wrapper over the unified scan-compiled
:class:`repro.train.engine.Engine`: it builds the :class:`TrainState`
and the Eq.-3 step/grad functions, picks an execution strategy
(``sequential`` / ``sync_mesh`` / ``async_ps`` — STRATEGY registry names),
and delegates the loop (scan compilation, buffer donation, host→device
prefetch, periodic checkpointing with exact resume) to the engine.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ssl_loss import SSLHyper
from repro.models.dnn import DNNConfig, dnn_forward, init_dnn
from repro.optim import Optimizer, adagrad, constant_lr, parallel_lr_schedule
from repro.train.engine import Engine, TrainState, data_mesh
from repro.train.train_step import dnn_ssl_grads, dnn_ssl_step

__all__ = ["TrainResult", "train_dnn_ssl", "evaluate_dnn"]


@dataclasses.dataclass
class TrainResult:
    params: dict
    history: list[dict]          # per-epoch metrics
    state: Any = None            # final engine TrainState (params/opt/rng/step)


def evaluate_dnn(params, X: np.ndarray, y: np.ndarray,
                 batch: int = 4096) -> float:
    correct = 0
    fwd = jax.jit(lambda p, x: jnp.argmax(dnn_forward(p, x), axis=-1))
    for s in range(0, len(X), batch):
        pred = fwd(params, jnp.asarray(X[s : s + batch]))
        correct += int((np.asarray(pred) == y[s : s + batch]).sum())
    return correct / len(X)


def train_dnn_ssl(
    pipeline_epoch: Callable[[], Iterable],
    *,
    cfg: DNNConfig,
    hyper: SSLHyper,
    n_epochs: int = 10,
    n_workers: int = 1,
    base_lr: float = 1e-3,
    lr_reset_epochs: int = 10,
    dropout: float = 0.2,
    eval_data: tuple[np.ndarray, np.ndarray] | None = None,
    eval_fn: Callable[[Any], dict] | None = None,
    seed: int = 0,
    opt: Optimizer | None = None,
    pairwise: str | Callable | None = "auto",
    mesh: jax.sharding.Mesh | None = None,
    strategy: str | None = None,
    scan_chunk: int = 16,
    prefetch: int = 2,
    max_staleness: int = 2,
    checkpoint_every: int = 0,
    checkpoint_dir: str | None = None,
    resume: bool = False,
    lr_schedule: Callable[[int], float] | None = None,
    params: dict | None = None,
    resilience=None,
    injector=None,
    capture_fn: Callable | None = None,
    capture_epochs: Callable[[int], bool] | Any = None,
    on_epoch_end: Callable[[int, Any, Any], None] | None = None,
) -> TrainResult:
    """Run the paper's training loop over ``pipeline_epoch`` batches.

    ``pairwise`` selects the Σ W_ij·Hc(p_i,p_j) implementation by PAIRWISE
    registry name — the default ``"auto"`` uses the fused Pallas kernel on
    TPU and the jnp oracle elsewhere — or is an already-resolved callable.

    ``strategy`` names a STRATEGY registry entry; when omitted it is
    inferred: ``"sync_mesh"`` if ``mesh`` (a ``("data",)`` mesh) is given —
    parameters replicated, each batch's leading worker axis sharded over it,
    the paper's k-worker synchronous SGD with pjit inserting the gradient
    all-reduce the parameter server performed — else ``"sequential"``.
    ``"async_ps"`` runs the §4 stale-gradient regime (``max_staleness``
    server steps of lag, dropout off — the async server pushes no rng).

    ``scan_chunk`` steps are compiled into one donated ``lax.scan`` (0 =
    the whole epoch — fastest, but the full epoch's batches are staged at
    once; the bounded default keeps host/device memory flat at big shapes);
    ``prefetch`` chunks are staged host→device ahead of compute.  ``checkpoint_every``/``checkpoint_dir`` enable periodic
    checkpoints; ``resume=True`` restores the newest one exactly (rng and
    step included).  ``params`` overrides the seeded init (back-compat for
    callers that pre-initialize).

    ``resilience`` (a ``ResilienceConfig``) turns on the engine's failure
    defenses — non-finite guard, checkpoint integrity/retention, prefetch
    supervision, async over-stale dropping; ``injector`` (a
    ``repro.resilience.FaultInjector``) arms deterministic fault injection
    for chaos testing.

    ``capture_fn(params, batch) -> array`` taps per-step embeddings inside
    the scan on epochs selected by ``capture_epochs``;
    ``on_epoch_end(epoch, params, captures)`` receives them stacked on
    host — the online graph-refresh hook (see ``repro.online``).
    """
    opt = opt or adagrad()
    key = jax.random.PRNGKey(seed)
    key, init_key = jax.random.split(key)
    if params is None:
        params = init_dnn(cfg, init_key)
    state = TrainState.create(params, opt.init(params), key)

    if strategy is None:
        strategy = "sync_mesh" if mesh is not None else "sequential"
    if strategy == "sync_mesh" and mesh is None:
        mesh = data_mesh(n_workers)
    if strategy == "async_ps" and dropout > 0.0:
        # The async server pushes no per-step rng to workers, so dropout
        # cannot be honored there — refuse rather than silently train a
        # different model than the caller configured.
        raise ValueError(
            "strategy 'async_ps' does not support dropout (the stale-"
            f"gradient workers are rng-free); got dropout={dropout}. "
            "Set dropout=0.0 explicitly.")

    # Resolve the pairwise kernel once; everything below passes the callable.
    from repro.api.registry import resolve_pairwise
    pairwise = resolve_pairwise(pairwise)

    def step_fn(s: TrainState, batch: dict, lr):
        # Same split order as the historical Python loop: carry keeps the
        # first subkey, the step consumes the second — bit-identical stream.
        rng, sub = jax.random.split(s.rng)
        p, o, metrics = dnn_ssl_step(
            s.params, s.opt_state, batch, cfg=cfg, hyper=hyper, opt=opt,
            lr=lr, dropout_rng=sub, dropout=dropout, pairwise=pairwise)
        return TrainState(params=p, opt_state=o, rng=rng,
                          step=s.step + 1), metrics

    def grad_fn(p, batch):  # async_ps: gradient at a (stale) snapshot
        return dnn_ssl_grads(p, batch, cfg=cfg, hyper=hyper,
                             dropout_rng=None, dropout=0.0,
                             pairwise=pairwise)

    engine = Engine(step_fn, grad_fn=grad_fn, opt=opt, strategy=strategy,
                    mesh=mesh, n_workers=n_workers,
                    max_staleness=max_staleness, scan_chunk=scan_chunk,
                    prefetch=prefetch, checkpoint_every=checkpoint_every,
                    checkpoint_dir=checkpoint_dir, resilience=resilience,
                    injector=injector, capture_fn=capture_fn)
    # The lr·k scaling rule compensates k-way gradient *averaging*; the
    # async server applies every pushed gradient individually, so its
    # reference regime keeps the base lr.
    schedule = lr_schedule or (
        constant_lr(base_lr) if strategy == "async_ps"
        else parallel_lr_schedule(base_lr, n_workers, lr_reset_epochs))
    if eval_fn is None and eval_data is not None:
        def eval_fn(p):
            return {"eval/acc": evaluate_dnn(jax.device_get(p), *eval_data)}
    res = engine.run(pipeline_epoch, state=state, n_epochs=n_epochs,
                     lr_schedule=schedule, eval_fn=eval_fn, resume=resume,
                     capture_epochs=capture_epochs, on_epoch_end=on_epoch_end)
    return TrainResult(params=res.state.params, history=res.history,
                       state=res.state)
