"""Sequential / data-parallel SSL training loop for the paper's experiments.

Reproduces the paper's §3 protocol: AdaGrad, base lr 1e-3, effective lr
``1e-3·k`` reset after 10 epochs, dropout 0.2, batch size 1024/2048, label
ratios 2–100%.  The same loop drives the fully-supervised baseline (γ=κ=0),
the random-batch baseline, and the meta-batch method — only the pipeline and
hyper-parameters change.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ssl_loss import SSLHyper
from repro.models.dnn import DNNConfig, dnn_forward, init_dnn
from repro.optim import Optimizer, adagrad, parallel_lr_schedule
from repro.train.train_step import dnn_ssl_step

__all__ = ["TrainResult", "train_dnn_ssl", "evaluate_dnn"]


@dataclasses.dataclass
class TrainResult:
    params: dict
    history: list[dict]          # per-epoch metrics


def evaluate_dnn(params, X: np.ndarray, y: np.ndarray,
                 batch: int = 4096) -> float:
    correct = 0
    fwd = jax.jit(lambda p, x: jnp.argmax(dnn_forward(p, x), axis=-1))
    for s in range(0, len(X), batch):
        pred = fwd(params, jnp.asarray(X[s : s + batch]))
        correct += int((np.asarray(pred) == y[s : s + batch]).sum())
    return correct / len(X)


def train_dnn_ssl(
    pipeline_epoch: Callable[[], Iterable],
    *,
    cfg: DNNConfig,
    hyper: SSLHyper,
    n_epochs: int = 10,
    n_workers: int = 1,
    base_lr: float = 1e-3,
    lr_reset_epochs: int = 10,
    dropout: float = 0.2,
    eval_data: tuple[np.ndarray, np.ndarray] | None = None,
    seed: int = 0,
    opt: Optimizer | None = None,
    pairwise: str | Callable | None = "auto",
    pairwise_impl=None,
    mesh: jax.sharding.Mesh | None = None,
) -> TrainResult:
    """Run the paper's training loop over ``pipeline_epoch`` batches.

    ``pairwise`` selects the Σ W_ij·Hc(p_i,p_j) implementation by PAIRWISE
    registry name — the default ``"auto"`` uses the fused Pallas kernel on
    TPU and the jnp oracle elsewhere.  ``pairwise_impl`` (raw callable) is
    deprecated.  When ``mesh`` (a ``("data",)`` mesh) is given, parameters
    are replicated and each batch's leading worker axis is sharded over it —
    the paper's k-worker synchronous SGD, with pjit inserting the gradient
    all-reduce the parameter server performed.
    """
    opt = opt or adagrad()
    key = jax.random.PRNGKey(seed)
    key, init_key = jax.random.split(key)
    params = init_dnn(cfg, init_key)
    opt_state = opt.init(params)
    schedule = parallel_lr_schedule(base_lr, n_workers, lr_reset_epochs)

    put_batch = jnp.asarray
    if mesh is not None:
        P = jax.sharding.PartitionSpec
        replicated = jax.sharding.NamedSharding(mesh, P())
        sharded = jax.sharding.NamedSharding(mesh, P("data"))
        params = jax.device_put(params, replicated)
        opt_state = jax.device_put(opt_state, replicated)
        put_batch = lambda v: jax.device_put(jnp.asarray(v), sharded)  # noqa: E731

    step_fn = jax.jit(
        lambda p, s, b, lr, rng: dnn_ssl_step(
            p, s, b, cfg=cfg, hyper=hyper, opt=opt, lr=lr,
            dropout_rng=rng, dropout=dropout, pairwise=pairwise,
            pairwise_impl=pairwise_impl))

    history = []
    for epoch in range(n_epochs):
        lr = jnp.float32(schedule(epoch))
        t0 = time.time()
        ms = []
        for batch in pipeline_epoch():
            key, rng = jax.random.split(key)
            jb = {k: put_batch(v) for k, v in dataclasses.asdict(batch).items()}
            params, opt_state, metrics = step_fn(params, opt_state, jb, lr, rng)
            ms.append(metrics)
        if not ms:
            # e.g. n_meta < n_workers: the pipeline had nothing to yield.
            warnings.warn(
                f"epoch {epoch}: pipeline yielded no batches "
                "(n_meta < n_workers?); skipping epoch row", stacklevel=2)
            continue
        row = {k: float(np.mean([float(m[k]) for m in ms])) for k in ms[0]}
        row.update(epoch=epoch, lr=float(lr), seconds=time.time() - t0)
        if eval_data is not None:
            row["eval/acc"] = evaluate_dnn(jax.device_get(params), *eval_data)
        history.append(row)
    return TrainResult(params=params, history=history)
