from .checkpoint import load_checkpoint, save_checkpoint
from .engine import (Engine, EngineResult, TrainState, data_mesh, lift_step,
                     prefetch_to_device)
from .train_step import (dnn_ssl_grads, dnn_ssl_step, lm_supervised_step,
                         lm_train_step)
from .trainer import TrainResult, evaluate_dnn, train_dnn_ssl
