"""Flat-npz checkpointing for arbitrary param/optimizer pytrees.

Path-keyed, so checkpoints are stable across process restarts and can be
saved from sharded arrays (``jax.device_get`` gathers before writing).
Paths are normalized to exactly one ``.npz`` suffix in both directions, so
callers may pass either a bare path or a ``.npz`` path to either function.

Each leaf's dtype *name* is stored alongside its bytes: numpy serializes
extension dtypes (bfloat16, float8) as raw void records, and the recorded
name lets ``load_checkpoint`` view them back losslessly instead of handing
the caller opaque ``V2`` buffers.
"""
from __future__ import annotations

import os

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint"]

_SEP = "::"
_DTYPE_PREFIX = "__dtype__" + _SEP


def _norm(path: str) -> str:
    """One ``.npz`` suffix, always — ``np.savez`` appends its own when the
    suffix is missing, which used to desync save/load paths."""
    return path if path.endswith(".npz") else path + ".npz"


def _key(path) -> str:
    return _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_key(path)] = np.asarray(jax.device_get(leaf))
    return flat


def _restore_dtype(arr: np.ndarray, name: str) -> np.ndarray:
    if arr.dtype.name == name:
        return arr
    try:
        dt = np.dtype(name)
    except TypeError:
        import ml_dtypes  # registered extension dtypes (bfloat16, fp8, …)
        dt = np.dtype(getattr(ml_dtypes, name))
    # Void records are the same bits under a lost dtype — reinterpret;
    # anything else genuinely changed representation in the archive.
    return arr.view(dt) if arr.dtype.kind == "V" else arr.astype(dt)


def save_checkpoint(path: str, tree) -> None:
    path = _norm(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    dtypes = {_DTYPE_PREFIX + k: np.str_(v.dtype.name)
              for k, v in flat.items()}
    np.savez(path, **flat, **dtypes)


def load_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (a template pytree).

    Leaves keep the dtype they were *saved* with (the template supplies
    structure and expected shapes only) — restoring must not silently cast
    e.g. a uint32 PRNG key or an int32 step counter to the template's dtype.
    """
    data = np.load(_norm(path))
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for p, leaf in leaves_with_path:
        key = _key(p)
        arr = data[key]
        if _DTYPE_PREFIX + key in data.files:   # absent in old checkpoints
            arr = _restore_dtype(arr, str(data[_DTYPE_PREFIX + key]))
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
