"""Flat-npz checkpointing for arbitrary param/optimizer pytrees.

Path-keyed, so checkpoints are stable across process restarts and can be
saved from sharded arrays (``jax.device_get`` gathers before writing).
"""
from __future__ import annotations

import os

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint"]

_SEP = "::"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(tree))


def load_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (a template pytree)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for p, leaf in leaves_with_path:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                        for q in p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        new_leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
