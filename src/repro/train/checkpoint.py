"""Flat-npz checkpointing for arbitrary param/optimizer pytrees.

Path-keyed, so checkpoints are stable across process restarts and can be
saved from sharded arrays (``jax.device_get`` gathers before writing).
Paths are normalized to exactly one ``.npz`` suffix in both directions, so
callers may pass either a bare path or a ``.npz`` path to either function.

Each leaf's dtype *name* is stored alongside its bytes: numpy serializes
extension dtypes (bfloat16, float8) as raw void records, and the recorded
name lets ``load_checkpoint`` view them back losslessly instead of handing
the caller opaque ``V2`` buffers.

Writes are **atomic**: bytes go to a ``.tmp`` sibling (fsynced) and land
via ``os.replace``, so a crash mid-save leaves the previous checkpoint
intact instead of a torn archive.  Each save also drops a ``.sha256``
sidecar; ``load_checkpoint`` verifies it (and wraps any unreadable
archive) as :class:`CheckpointCorruptError`, which the engine's fallback
path uses to skip to the newest *valid* checkpoint.
"""
from __future__ import annotations

import hashlib
import os

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointCorruptError",
           "atomic_write_text"]


class CheckpointCorruptError(RuntimeError):
    """The archive's bytes do not match its checksum sidecar, or the
    archive cannot be read back into the template at all."""

_SEP = "::"
_DTYPE_PREFIX = "__dtype__" + _SEP


def _norm(path: str) -> str:
    """One ``.npz`` suffix, always — ``np.savez`` appends its own when the
    suffix is missing, which used to desync save/load paths."""
    return path if path.endswith(".npz") else path + ".npz"


def _key(path) -> str:
    return _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_key(path)] = np.asarray(jax.device_get(leaf))
    return flat


def _restore_dtype(arr: np.ndarray, name: str) -> np.ndarray:
    if arr.dtype.name == name:
        return arr
    try:
        dt = np.dtype(name)
    except TypeError:
        import ml_dtypes  # registered extension dtypes (bfloat16, fp8, …)
        dt = np.dtype(getattr(ml_dtypes, name))
    # Void records are the same bits under a lost dtype — reinterpret;
    # anything else genuinely changed representation in the archive.
    return arr.view(dt) if arr.dtype.kind == "V" else arr.astype(dt)


def _atomic_write_bytes(path: str, write_fn) -> None:
    """Run ``write_fn(file_object)`` against ``path + ".tmp"`` and publish
    via ``os.replace`` — the file either keeps its old bytes or gets the
    complete new ones, never a torn mix."""
    tmp = path + ".tmp"
    try:
        with open(tmp, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def atomic_write_text(path: str, text: str) -> None:
    """Atomic replacement for ``open(path, "w").write(text)`` — used for
    the LATEST pointer and meta sidecars too, not just archives."""
    _atomic_write_bytes(path, lambda f: f.write(text.encode()))


def _digest(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def save_checkpoint(path: str, tree, *, checksum: bool = True) -> None:
    path = _norm(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    dtypes = {_DTYPE_PREFIX + k: np.str_(v.dtype.name)
              for k, v in flat.items()}
    # Write through a file object: np.savez would append a second ".npz"
    # to a bare ".tmp" path, desyncing the replace target.
    _atomic_write_bytes(path, lambda f: np.savez(f, **flat, **dtypes))
    if checksum:
        atomic_write_text(path + ".sha256", _digest(path) + "\n")


def load_checkpoint(path: str, like, *, verify: bool = True):
    """Restore into the structure of ``like`` (a template pytree).

    Leaves keep the dtype they were *saved* with (the template supplies
    structure and expected shapes only) — restoring must not silently cast
    e.g. a uint32 PRNG key or an int32 step counter to the template's dtype.

    With ``verify=True`` (default) the ``.sha256`` sidecar, when present,
    is checked before the archive is opened; a mismatch — or any failure
    to read the archive back into the template — raises
    :class:`CheckpointCorruptError` so callers can fall back to an older
    checkpoint instead of crashing on a torn file.
    """
    path = _norm(path)
    sidecar = path + ".sha256"
    if verify and os.path.exists(sidecar):
        with open(sidecar) as f:
            expected = f.read().strip()
        actual = _digest(path)
        if actual != expected:
            raise CheckpointCorruptError(
                f"{path}: sha256 mismatch (expected {expected[:12]}…, "
                f"got {actual[:12]}…) — file corrupted after save")
    try:
        data = np.load(path)
        leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
        new_leaves = []
        for p, leaf in leaves_with_path:
            key = _key(p)
            arr = data[key]
            if _DTYPE_PREFIX + key in data.files:  # absent in old checkpoints
                arr = _restore_dtype(arr, str(data[_DTYPE_PREFIX + key]))
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            new_leaves.append(arr)
    except CheckpointCorruptError:
        raise
    except Exception as e:
        raise CheckpointCorruptError(
            f"{path}: unreadable checkpoint ({type(e).__name__}: {e})") from e
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
