"""Asynchronous SGD variant (the paper's §4 future work, implemented).

Simulates a parameter-server async regime faithfully in a single process:
``k`` workers each hold a possibly-STALE copy of the parameters (up to
``max_staleness`` server steps old) and push gradients computed on their own
meta-batch; the server applies each pushed gradient immediately (no
synchronization barrier).  This reproduces the async trade-off the paper
anticipates: more updates per wall-clock unit, noisier/staler gradients.

The simulation is exact w.r.t. the update sequence an async parameter server
would produce under a round-robin arrival schedule with fixed per-worker
delay — deterministic, so it is testable.

``train_dnn_ssl_async`` is now a thin back-compat wrapper: the regime lives
in the unified engine as the ``"async_ps"`` STRATEGY entry (one scan body,
sharing ``dnn_ssl_grads`` and the PAIRWISE registry with the synchronous
path) — see :mod:`repro.train.engine`.
"""
from __future__ import annotations

from typing import Callable, Iterable

import jax

from repro.core.ssl_loss import SSLHyper
from repro.models.dnn import DNNConfig, init_dnn
from repro.optim import Optimizer, constant_lr
from repro.train.trainer import train_dnn_ssl

__all__ = ["train_dnn_ssl_async"]


def train_dnn_ssl_async(
    pipeline_epoch: Callable[[], Iterable],
    *,
    cfg: DNNConfig,
    hyper: SSLHyper,
    n_epochs: int = 10,
    n_workers: int = 4,
    max_staleness: int = 2,
    base_lr: float = 1e-3,
    seed: int = 0,
    opt: Optimizer | None = None,
    eval_fn: Callable | None = None,
    pairwise: str | Callable | None = None,
    scan_chunk: int = 16,
):
    """Async SSL training. ``pipeline_epoch`` must yield (1, P, ·) batches
    (n_workers=1 pipelines); workers consume them round-robin.

    Returns ``(params, history)`` — the historical contract.  The reference
    regime used a constant lr and initialized straight from
    ``PRNGKey(seed)``; both are preserved here.
    """
    res = train_dnn_ssl(
        pipeline_epoch,
        cfg=cfg,
        hyper=hyper,
        n_epochs=n_epochs,
        n_workers=n_workers,
        base_lr=base_lr,
        dropout=0.0,
        seed=seed,
        opt=opt,
        pairwise=pairwise,
        strategy="async_ps",
        max_staleness=max_staleness,
        scan_chunk=scan_chunk,
        lr_schedule=constant_lr(base_lr),
        params=init_dnn(cfg, jax.random.PRNGKey(seed)),
        eval_fn=(None if eval_fn is None
                 else (lambda p: {"eval/acc": float(eval_fn(p))})),
    )
    return res.params, res.history
