"""Asynchronous SGD variant (the paper's §4 future work, implemented).

Simulates a parameter-server async regime faithfully in a single process:
``k`` workers each hold a possibly-STALE copy of the parameters (up to
``max_staleness`` server steps old) and push gradients computed on their own
meta-batch; the server applies each pushed gradient immediately (no
synchronization barrier).  This reproduces the async trade-off the paper
anticipates: more updates per wall-clock unit, noisier/staler gradients.

The simulation is exact w.r.t. the update sequence an async parameter server
would produce under a round-robin arrival schedule with fixed per-worker
delay — deterministic, so it is testable.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ssl_loss import SSLHyper
from repro.models.dnn import DNNConfig
from repro.optim import Optimizer, adagrad
from repro.train.train_step import dnn_ssl_loss

__all__ = ["train_dnn_ssl_async"]


def train_dnn_ssl_async(
    pipeline_epoch: Callable[[], Iterable],
    *,
    cfg: DNNConfig,
    hyper: SSLHyper,
    n_epochs: int = 10,
    n_workers: int = 4,
    max_staleness: int = 2,
    base_lr: float = 1e-3,
    seed: int = 0,
    opt: Optimizer | None = None,
    eval_fn: Callable | None = None,
):
    """Async SSL training. ``pipeline_epoch`` must yield (1, P, ·) batches
    (n_workers=1 pipelines); workers consume them round-robin."""
    from repro.models.dnn import init_dnn

    opt = opt or adagrad()
    params = init_dnn(cfg, jax.random.PRNGKey(seed))
    opt_state = opt.init(params)

    grad_fn = jax.jit(
        lambda p, b: jax.grad(
            lambda q: dnn_ssl_loss(q, b, cfg, hyper)[0])(p))
    update_fn = jax.jit(
        lambda g, s, p, lr: opt.update(g, s, p, lr))

    # Each worker's stale parameter snapshot (staleness grows with k and
    # delay; snapshots refresh when the worker pushes).
    snapshots = [params] * n_workers
    ages = [0] * n_workers
    history = []
    for epoch in range(n_epochs):
        losses = []
        for step, batch in enumerate(pipeline_epoch()):
            w = step % n_workers
            jb = {k: jnp.asarray(v)
                  for k, v in dataclasses.asdict(batch).items()}
            # Worker w computes a gradient on its (stale) snapshot...
            g = grad_fn(snapshots[w], jb)
            # ...the server applies it to the CURRENT params immediately.
            params, opt_state = update_fn(g, opt_state, params,
                                          jnp.float32(base_lr))
            ages[w] += 1
            # Snapshot refresh: worker pulls fresh params after its push,
            # but only every `max_staleness` pushes (simulated delay).
            if ages[w] >= max_staleness:
                snapshots[w] = params
                ages[w] = 0
        row = {"epoch": epoch}
        if eval_fn is not None:
            row["eval/acc"] = float(eval_fn(params))
        history.append(row)
    return params, history
