"""Sharding strategies: how params / batches / caches map onto the mesh.

Three selectable strategies (``--sharding``):

  dp       — the PAPER-FAITHFUL baseline.  §2.3's k-worker synchronous SGD:
             parameters replicated on every chip, the batch axis sharded over
             ("pod","data"); pjit's gradient all-reduce plays the parameter
             server.  The 'model' axis is idle — exactly as the paper's
             scheme would run on this mesh.
  fsdp     — beyond-paper: ZeRO-style parameter/optimizer sharding over the
             data axes (largest divisible dim of each param).
  fsdp_tp  — beyond-paper: fsdp + tensor/expert parallelism over the 'model'
             axis (heads / d_ff / vocab / experts), name-driven rules.

Specs are attached to ShapeDtypeStructs, so the dry-run lowers exactly what
the launcher would run.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

STRATEGIES = ("dp", "fsdp", "fsdp_tp")


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axes(mesh: Mesh) -> tuple[str, ...]:
    return batch_axes(mesh)


def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


# --------------------------------------------------------------- params
# Name-driven tensor-parallel dim preferences: leaf name -> candidate dims
# (index into the *unstacked* shape; stacked params shift by +1).
_TP_DIM_RULES: dict[str, tuple[int, ...]] = {
    "table": (0,),          # vocab
    "lm_head": (1,),        # vocab
    "modality_proj": (1,),
    "wq": (1,), "wk": (1,), "wv": (1,),   # head dim
    "wo": (0,),                            # head dim
    "wg": (1, 2), "wu": (1, 2), "wd": (0, 1),   # mlp (d,f)/(f,d); moe (E,d,f)
    "router": (1,),
    "in_proj": (1,), "out_proj": (0,), "x_proj": (0,),
    "conv_w": (1,), "conv_b": (0,), "dt_proj_w": (1,), "dt_proj_b": (0,),
    "A_log": (0,), "D": (0,),
    "up": (1,), "down": (0,), "up_g": (1,), "up_u": (1,),
    "wi": (0,), "wf": (0,),
}
_MOE_LEAVES = {"wg", "wu", "wd"}  # under a "moe" parent: prefer expert dim 0


def spec_for_param(path: str, shape: tuple[int, ...], mesh: Mesh,
                   strategy: str) -> P:
    if strategy == "dp" or len(shape) == 0:
        return P()
    leaf = path.rsplit("/", 1)[-1]
    stacked = "superblocks" in path
    off = 1 if stacked else 0
    spec: list[Any] = [None] * len(shape)
    model_n = mesh.shape.get("model", 1)
    fa = fsdp_axes(mesh)
    fsdp_n = _axes_size(mesh, fa)

    # -- tensor parallel dim (fsdp_tp only) --
    if strategy == "fsdp_tp":
        cands = list(_TP_DIM_RULES.get(leaf, ()))
        if "/moe/" in path + "/" and leaf in _MOE_LEAVES:
            # Expert-parallel first; else Megatron column-parallel: shard the
            # d_ff dim of up/gate (dim 2 of (E,d,f)) so only the down-proj
            # (row-parallel, f contracting) all-reduces the small (·,d)
            # output — never the (·,f) intermediate (§Perf mixtral iter 1).
            cands = [0, 2] if leaf in ("wu", "wg") else [0, 1]
        for c in cands:
            d = c + off
            if d < len(shape) and shape[d] % model_n == 0 and shape[d] >= model_n:
                spec[d] = "model"
                break

    # -- fsdp dim: largest remaining divisible dim (skip scan dim) --
    order = sorted(range(off, len(shape)), key=lambda d: -shape[d])
    for d in order:
        if spec[d] is None and shape[d] % fsdp_n == 0 and shape[d] >= fsdp_n:
            spec[d] = fa if len(fa) > 1 else fa[0]
            break
    return P(*spec)


def param_shardings(param_shapes, mesh: Mesh, strategy: str):
    """Map a pytree of ShapeDtypeStructs -> same tree of NamedShardings."""

    def go(path, leaf):
        spec = spec_for_param(_path_str(path), leaf.shape, mesh, strategy)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(go, param_shapes)


def with_shardings(shapes_tree, shardings_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree, shardings_tree)


# --------------------------------------------------------------- batches
def train_batch_shardings(batch_shapes, mesh: Mesh):
    """Shard the leading (batch/group) axis of every train input over the
    data axes; everything else replicated."""
    ba = batch_axes(mesh)
    bn = _axes_size(mesh, ba)

    def go(path, leaf):
        if len(leaf.shape) and leaf.shape[0] % bn == 0 and leaf.shape[0] >= bn:
            spec = P(ba if len(ba) > 1 else ba[0])
        else:
            spec = P()
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(go, batch_shapes)


# ---------------------------------------------------------------- caches
def spec_for_cache(path: str, shape: tuple[int, ...], mesh: Mesh,
                   batch_size: int, strategy: str) -> P:
    """Decode-cache sharding.

    Batch dim over data axes when divisible; for global_batch=1
    (long_500k) the KV sequence dim is sharded over data instead
    (sequence-parallel decode — softmax reductions become collectives).
    KV-head dims go on 'model' when divisible under fsdp_tp.
    """
    leaf = path.rsplit("/", 1)[-1]
    stacked = "first" not in path.split("/")
    off = 1 if stacked else 0        # leading L dim from stacking
    ba = batch_axes(mesh)
    bn = _axes_size(mesh, ba)
    model_n = mesh.shape.get("model", 1) if strategy == "fsdp_tp" else 1
    spec: list[Any] = [None] * len(shape)
    b_dim = off                       # batch dim position
    batch_ok = (b_dim < len(shape) and shape[b_dim] % bn == 0
                and shape[b_dim] >= bn)
    if batch_ok:
        spec[b_dim] = ba if len(ba) > 1 else ba[0]
    if leaf in ("k", "v", "positions", "valid"):
        s_dim = off + 1
        if not batch_ok and s_dim < len(shape) and shape[s_dim] % bn == 0:
            spec[s_dim] = ba if len(ba) > 1 else ba[0]
        if leaf in ("k", "v") and model_n > 1:
            kv_dim = off + 2
            if shape[kv_dim] % model_n == 0 and shape[kv_dim] >= model_n:
                spec[kv_dim] = "model"
    elif leaf in ("conv", "ssm") and model_n > 1:
        di_dim = off + 2 if leaf == "conv" else off + 1
        if di_dim < len(shape) and shape[di_dim] % model_n == 0:
            spec[di_dim] = "model"
    return P(*spec)


def cache_shardings(cache_shapes, mesh: Mesh, batch_size: int, strategy: str):
    def go(path, leaf):
        spec = spec_for_cache(_path_str(path), leaf.shape, mesh, batch_size,
                              strategy)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(go, cache_shapes)
