"""Data-parallel SSL training across k workers (paper §2.3 / Fig 3b).

The leading worker axis of each batch is sharded over a ``data`` mesh axis
backed by k host devices — the same pjit pattern the production launcher
uses on the 16×16 pod mesh — with the paper's lr = 0.001·k rule.

    python examples/parallel_ssl.py --workers 4 --epochs 6
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--workers", type=int, default=4)
ap.add_argument("--epochs", type=int, default=6)
args = ap.parse_args()

# Device count must be set before jax initializes.
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={args.workers}")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import SSLHyper, build_affinity_graph, plan_meta_batches  # noqa: E402
from repro.data import MetaBatchPipeline, drop_labels, make_corpus  # noqa: E402
from repro.models.dnn import DNNConfig, init_dnn  # noqa: E402
from repro.optim import adagrad, parallel_lr_schedule  # noqa: E402
from repro.train import evaluate_dnn  # noqa: E402
from repro.train.train_step import dnn_ssl_step  # noqa: E402


def main():
    k = args.workers
    mesh = jax.make_mesh((k,), ("data",))
    P = jax.sharding.PartitionSpec
    rep = jax.sharding.NamedSharding(mesh, P())
    shard0 = jax.sharding.NamedSharding(mesh, P("data"))

    full = make_corpus(5000, n_classes=16, input_dim=128, manifold_dim=10,
                       seed=0)
    corpus = dataclasses.replace(full, X=full.X[:4000], y=full.y[:4000],
                                 label_mask=full.label_mask[:4000])
    test = (full.X[4000:], full.y[4000:])
    labeled = drop_labels(corpus, 0.05, seed=1)     # the paper's 5% scenario
    graph = build_affinity_graph(corpus.X, k=10)
    plan = plan_meta_batches(graph, batch_size=256, n_classes=16, seed=0)
    pipe = MetaBatchPipeline(labeled, graph, plan, n_workers=k, seed=0)

    cfg = DNNConfig(input_dim=128, hidden_dim=512, n_hidden=3, n_classes=16,
                    dropout=0.0)
    hyper = SSLHyper(1.0, 1e-4, 1e-5)
    opt = adagrad()
    params = jax.device_put(init_dnn(cfg, jax.random.PRNGKey(0)), rep)
    opt_state = jax.device_put(opt.init(params), rep)
    schedule = parallel_lr_schedule(1e-3, n_workers=k, reset_epochs=10)

    @jax.jit
    def step(params, opt_state, batch, lr):
        return dnn_ssl_step(params, opt_state, batch, cfg=cfg, hyper=hyper,
                            opt=opt, lr=lr)

    print(f"mesh: {mesh} — worker axis sharded over {k} devices; "
          f"lr rule: 0.001·{k} for 10 epochs, then 0.001")
    with mesh:
        for epoch in range(args.epochs):
            lr = jnp.float32(schedule(epoch))
            for batch in pipe.epoch():
                jb = {key: jax.device_put(jnp.asarray(v), shard0)
                      for key, v in dataclasses.asdict(batch).items()}
                params, opt_state, metrics = step(params, opt_state, jb, lr)
            acc = evaluate_dnn(jax.device_get(params), *test)
            print(f"epoch {epoch}: lr={float(lr):.4f} "
                  f"loss={float(metrics['loss/total']):.4f} "
                  f"val_acc={acc:.4f}")


if __name__ == "__main__":
    main()
