"""Data-parallel SSL training across k workers (paper §2.3 / Fig 3b).

Driven end to end by ``repro.api``: ``TrainConfig(execution="parallel")``
makes the trainer shard each batch's leading worker axis over a ``("data",)``
mesh — the same pjit pattern the production launcher uses on the 16x16 pod
mesh — with the paper's lr = 0.001*k rule applied by the schedule.

    python examples/parallel_ssl.py --workers 4 --epochs 6
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--workers", type=int, default=4)
ap.add_argument("--epochs", type=int, default=6)
args = ap.parse_args()

# Device count must be set before jax initializes.
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={args.workers}")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import (BatchConfig, DataConfig, Experiment,  # noqa: E402
                       ExperimentConfig, ObjectiveConfig, TrainConfig)


def main():
    k = args.workers
    cfg = ExperimentConfig(
        name=f"parallel-{k}w",
        data=DataConfig(n=4000, n_classes=16, input_dim=128, manifold_dim=10,
                        label_ratio=0.05),          # the paper's 5% scenario
        batch=BatchConfig(batch_size=256),
        objective=ObjectiveConfig(gamma=1.0, kappa=1e-4, weight_decay=1e-5),
        train=TrainConfig(n_epochs=args.epochs, n_workers=k,
                          execution="parallel", base_lr=1e-3,
                          lr_reset_epochs=10, dropout=0.0,
                          hidden_dim=512, n_hidden=3))

    print(f"worker axis sharded over {k} logical devices; "
          f"lr rule: 0.001*{k} for 10 epochs, then 0.001")
    res = Experiment(cfg).run()
    for row in res.history:
        print(f"epoch {row['epoch']}: lr={row['lr']:.4f} "
              f"loss={row['loss/total']:.4f} "
              f"val_acc={row['eval/acc']:.4f}")
    print(f"done in {res.seconds:.1f}s")


if __name__ == "__main__":
    main()
