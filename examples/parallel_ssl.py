"""Data-parallel SSL training across k workers (paper §2.3 / Fig 3b).

Driven end to end by ``repro.api`` through the unified training engine:
``--strategy`` picks the STRATEGY registry entry by name —

  * ``sync_mesh`` shards each batch's leading worker axis over a
    ``("data",)`` mesh (the same pjit pattern the production launcher uses
    on the 16x16 pod mesh), with the paper's lr = 0.001*k rule;
  * ``async_ps``  runs the §4 stale-gradient parameter-server regime;
  * ``sequential`` keeps the vmapped k-worker step on one device.

    python examples/parallel_ssl.py --workers 4 --epochs 6
    python examples/parallel_ssl.py --workers 4 --strategy async_ps
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--workers", type=int, default=4)
ap.add_argument("--epochs", type=int, default=6)
ap.add_argument("--strategy", default="sync_mesh",
                choices=["sequential", "sync_mesh", "async_ps"])
ap.add_argument("--scan-chunk", type=int, default=0,
                help="steps per compiled lax.scan (0 = whole epoch)")
args = ap.parse_args()

# Device count must be set before jax initializes.
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={args.workers}")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import (BatchConfig, DataConfig, Experiment,  # noqa: E402
                       ExecutionConfig, ExperimentConfig, ObjectiveConfig,
                       TrainConfig)


def main():
    k = args.workers
    cfg = ExperimentConfig(
        name=f"parallel-{k}w-{args.strategy}",
        data=DataConfig(n=4000, n_classes=16, input_dim=128, manifold_dim=10,
                        label_ratio=0.05),          # the paper's 5% scenario
        batch=BatchConfig(batch_size=256),
        objective=ObjectiveConfig(gamma=1.0, kappa=1e-4, weight_decay=1e-5),
        train=TrainConfig(n_epochs=args.epochs, n_workers=k,
                          base_lr=1e-3, lr_reset_epochs=10, dropout=0.0,
                          hidden_dim=512, n_hidden=3),
        execution=ExecutionConfig(strategy=args.strategy,
                                  scan_chunk=args.scan_chunk))

    if args.strategy == "sync_mesh":
        print(f"worker axis sharded over {k} logical devices; "
              f"lr rule: 0.001*{k} for 10 epochs, then 0.001")
    elif args.strategy == "async_ps":
        print(f"{k} async workers pushing stale gradients "
              "(max_staleness=2, round-robin server)")
    res = Experiment(cfg).run()
    for row in res.history:
        print(f"epoch {row['epoch']}: lr={row['lr']:.4f} "
              f"loss={row['loss/total']:.4f} "
              f"val_acc={row['eval/acc']:.4f}")
    print(f"done in {res.seconds:.1f}s")


if __name__ == "__main__":
    main()
