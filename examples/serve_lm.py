"""Batched serving demo: prefill a batch of prompts, then decode with the
per-layer cache machinery (full KV / ring KV / SSM state) and sampling.

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b --steps 24
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as tf
from repro.serve.decode import serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()   # CPU-sized variant of the family
    print(f"serving {cfg.name} ({cfg.param_count()/1e6:.1f}M params, "
          f"pattern={cfg.block_pattern})")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    _, cache = tf.prefill(params, cfg, prompts,
                          cache_len=args.prompt_len + args.steps)
    print(f"prefill: batch={args.batch} len={args.prompt_len} "
          f"in {time.time()-t0:.2f}s")

    step = jax.jit(lambda c, t, p, k: serve_step(
        params, cfg, c, t, p, k, temperature=args.temperature))
    cur = prompts[:, -1:]
    toks = []
    t0 = time.time()
    for s in range(args.steps):
        key, sub = jax.random.split(key)
        pos = jnp.full((args.batch,), args.prompt_len + s - 1, jnp.int32)
        cur, cache = step(cache, cur, pos, sub)
        toks.append(cur)
    dt = time.time() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"decoded {args.steps} tokens/seq in {dt:.2f}s "
          f"({args.batch*args.steps/dt:.1f} tok/s)")
    for b in range(args.batch):
        print(f"  seq{b}: {out[b].tolist()}")


if __name__ == "__main__":
    main()
