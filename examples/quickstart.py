"""Quickstart: graph-regularized semi-supervised training via ``repro.api``.

One ``ExperimentConfig`` describes the whole pipeline — synthetic corpus,
k-NN affinity graph, balanced partition, meta-batch synthesis, and the
Eq.-3 objective; ``Experiment.run()`` does the rest.  No hand-wiring of
graph/plan/pipeline: components are selected by registry name in the config
(``repro.api.registry`` lists them).

    PYTHONPATH=src python examples/quickstart.py [--epochs 10]
    PYTHONPATH=src python examples/quickstart.py --pairwise pallas
"""
import argparse
import dataclasses

from repro.api import (BatchConfig, DataConfig, Experiment, ExperimentConfig,
                       GraphConfig, ObjectiveConfig, TrainConfig)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--label-ratio", type=float, default=0.02)
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--pairwise", default="auto",
                    choices=["auto", "ref", "pallas", "fused"],
                    help="pairwise-kernel registry entry")
    args = ap.parse_args()

    cfg = ExperimentConfig(
        name="quickstart",
        data=DataConfig(n=args.n, n_classes=16, input_dim=128,
                        manifold_dim=10, label_ratio=args.label_ratio),
        graph=GraphConfig(builder="knn_rbf", k=10),
        batch=BatchConfig(pipeline="meta_batch", batch_size=512),
        objective=ObjectiveConfig(gamma=args.gamma, kappa=1e-4,
                                  weight_decay=1e-5, pairwise=args.pairwise),
        train=TrainConfig(n_epochs=args.epochs, base_lr=1e-2, dropout=0.0,
                          hidden_dim=512, n_hidden=3))

    # The supervised baseline is the same experiment with γ = κ = 0.
    supervised = dataclasses.replace(
        cfg, name="supervised",
        objective=dataclasses.replace(cfg.objective, gamma=0.0, kappa=0.0))

    exp = Experiment(cfg).build()
    print(f"corpus: {exp.corpus.n} points, "
          f"{int(exp.corpus.label_mask.sum())} labeled "
          f"({100 * exp.corpus.label_ratio():.1f}%)")
    print(f"graph: {exp.graph.n_nodes} nodes, {exp.graph.n_edges} edges; "
          f"{exp.plan.mini_block_labels.max() + 1} mini-blocks -> "
          f"{exp.plan.n_meta} meta-batches")

    print(f"training SSL (gamma={args.gamma:.2f}, "
          f"pairwise={args.pairwise!r}) vs fully-supervised...")
    for experiment in (exp, Experiment(supervised, corpus=exp.corpus,
                                       eval_data=exp.eval_data,
                                       graph=exp.graph, plan=exp.plan)):
        res = experiment.run()
        accs = " ".join(f"{h['eval/acc']:.3f}" for h in res.history)
        print(f"   {res.config.name:<11} acc by epoch: {accs}")


if __name__ == "__main__":
    main()
