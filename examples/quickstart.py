"""Quickstart: graph-regularized semi-supervised training, end to end.

Builds the synthetic TIMIT-like corpus, the k-NN affinity graph, the
partitioned meta-batches, and trains the paper's DNN with the Eq.-3
objective at 2% labels — comparing against the fully-supervised baseline.

    PYTHONPATH=src python examples/quickstart.py [--epochs 10]
"""
import argparse
import dataclasses

from repro.core import SSLHyper, build_affinity_graph, plan_meta_batches
from repro.data import MetaBatchPipeline, drop_labels, make_corpus
from repro.models.dnn import DNNConfig
from repro.train import train_dnn_ssl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--label-ratio", type=float, default=0.02)
    ap.add_argument("--gamma", type=float, default=1.0)
    args = ap.parse_args()

    print("1) synthesizing corpus + affinity graph (k=10, RBF weights)…")
    full = make_corpus(int(args.n * 1.25), n_classes=16, input_dim=128,
                       manifold_dim=10, seed=0)
    corpus = dataclasses.replace(
        full, X=full.X[: args.n], y=full.y[: args.n],
        label_mask=full.label_mask[: args.n])
    test = (full.X[args.n:], full.y[args.n:])
    labeled = drop_labels(corpus, args.label_ratio, seed=1)
    graph = build_affinity_graph(corpus.X, k=10)
    print(f"   {graph.n_nodes} nodes, {graph.n_edges} edges, "
          f"{int(labeled.label_mask.sum())} labeled "
          f"({100 * labeled.label_ratio():.1f}%)")

    print("2) partitioning graph into mini-blocks + synthesizing meta-batches…")
    plan = plan_meta_batches(graph, batch_size=512, n_classes=16, seed=0)
    print(f"   {plan.mini_block_labels.max() + 1} mini-blocks → "
          f"{plan.n_meta} meta-batches")

    cfg = DNNConfig(input_dim=128, hidden_dim=512, n_hidden=3, n_classes=16,
                    dropout=0.0)
    pipe = MetaBatchPipeline(labeled, graph, plan, n_workers=1, seed=0)
    print("3) training SSL (γ=%.2f) vs fully-supervised…" % args.gamma)
    for name, hyper in [("ssl", SSLHyper(args.gamma, 1e-4, 1e-5)),
                        ("supervised", SSLHyper(0.0, 0.0, 1e-5))]:
        res = train_dnn_ssl(pipe.epoch, cfg=cfg, hyper=hyper,
                            n_epochs=args.epochs, dropout=0.0, base_lr=1e-2,
                            eval_data=test, seed=0)
        accs = [h["eval/acc"] for h in res.history]
        print(f"   {name:<11} acc by epoch: "
              + " ".join(f"{a:.3f}" for a in accs))


if __name__ == "__main__":
    main()
