"""End-to-end driver: train a transformer LM with the paper's graph-SSL
objective on a synthetic topic-structured token corpus.

The sequence-level affinity graph (bag-of-tokens k-NN, DESIGN.md §3) feeds
the Eq.-3 regularizer on the pooled output distribution while the usual
next-token CE trains the LM.  Components come from the ``repro.api``
registries: the graph builder and the pairwise Hc(p_i,p_j) kernel are both
selected by name (``--pairwise auto`` uses the fused Pallas kernel on TPU).
``--scale`` picks the model size:

  small (default, CPU-friendly ≈ 11M params) | mid ≈ 40M | large ≈ 110M

    PYTHONPATH=src python examples/train_lm_ssl.py --steps 60
    PYTHONPATH=src python examples/train_lm_ssl.py --scale large --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import AFFINITY
from repro.core import SSLHyper, plan_meta_batches
from repro.core.metabatch import NeighborSampler
from repro.data import make_token_corpus, sequence_features
from repro.models import transformer as tf
from repro.models.config import ATTN, ModelConfig
from repro.optim import adagrad
from repro.train.train_step import lm_train_step

SCALES = {
    "small": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                  d_ff=1024, vocab_size=8192),
    "mid": dict(n_layers=8, d_model=448, n_heads=8, n_kv_heads=4,
                d_ff=1792, vocab_size=16384),
    "large": dict(n_layers=12, d_model=640, n_heads=10, n_kv_heads=5,
                  d_ff=2560, vocab_size=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=SCALES, default="small")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--gamma", type=float, default=0.05)
    ap.add_argument("--graph-builder", default="knn_rbf",
                    help="AFFINITY registry entry")
    ap.add_argument("--pairwise", default="auto",
                    choices=["auto", "ref", "pallas", "fused"],
                    help="PAIRWISE registry entry")
    args = ap.parse_args()

    cfg = ModelConfig(name=f"lm-{args.scale}", family="dense",
                      block_pattern=(ATTN,), activation="swiglu",
                      norm="rmsnorm", dtype="float32", rope_theta=1e4,
                      **SCALES[args.scale])
    print(f"model: {cfg.name}  params≈{cfg.param_count()/1e6:.1f}M")

    n_seqs = 512
    toks, topics = make_token_corpus(n_seqs, args.seq_len + 1,
                                     cfg.vocab_size, n_topics=8, seed=0)
    feats = sequence_features(toks, cfg.vocab_size, dim=64, seed=0)
    graph = AFFINITY.get(args.graph_builder)(feats, k=10)
    plan = plan_meta_batches(graph, batch_size=args.batch, n_classes=4,
                             seed=0)
    sampler = NeighborSampler(plan.batch_edges, seed=0)
    # "labels" for the SSL head: the latent topic of 5% of sequences.
    rng = np.random.default_rng(0)
    label_mask = rng.random(n_seqs) < 0.05
    print(f"{n_seqs} sequences, affinity graph {graph.n_edges} edges, "
          f"{plan.n_meta} meta-batches, {label_mask.sum()} topic labels")

    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    opt = adagrad()
    opt_state = opt.init(params)
    hyper = SSLHyper(gamma=args.gamma, kappa=1e-4, weight_decay=0.0)

    @jax.jit
    def step(params, opt_state, batch):
        return lm_train_step(params, opt_state, batch, cfg=cfg, hyper=hyper,
                             opt=opt, lr=jnp.float32(3e-3),
                             pairwise=args.pairwise)

    t0 = time.time()
    i = 0
    while i < args.steps:
        order = np.random.default_rng(i).permutation(plan.n_meta)
        for mi in order:
            nb = sampler.sample(int(mi))
            idx = plan.meta_batches[mi]
            if nb is not None:
                idx = np.concatenate([idx, plan.meta_batches[nb]])
            idx = idx[: args.batch * 2]
            if len(idx) < args.batch * 2:   # pad to static shape
                idx = np.pad(idx, (0, args.batch * 2 - len(idx)),
                             mode="edge")
            W = graph.dense_block(idx)
            batch = {
                "tokens": jnp.asarray(toks[idx][:, :-1]),
                "targets": jnp.asarray(toks[idx][:, 1:]),
                "loss_mask": jnp.ones((len(idx), args.seq_len), jnp.float32),
                "W": jnp.asarray(W, jnp.float32)[None],
                "seq_labels": jnp.asarray(topics[idx], jnp.int32)[None],
                "seq_label_mask": jnp.asarray(
                    label_mask[idx], jnp.float32)[None],
            }
            params, opt_state, metrics = step(params, opt_state, batch)
            if i % 10 == 0:
                print(f"step {i:4d}: ce={float(metrics['loss/ce']):.4f} "
                      f"ssl_graph={float(metrics.get('ssl/graph', 0)):.4f} "
                      f"({(time.time() - t0):.1f}s)")
            i += 1
            if i >= args.steps:
                break
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
